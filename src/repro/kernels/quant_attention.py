"""Pallas TPU kernels: single-pass flash-decoding attention over the
hierarchical quantized KV cache (QuantSpec §5.2.1, adapted to TPU).

One kernel invocation covers the **whole** hierarchical cache — the
quantized region *and* the recent-token FP buffer — as one online-softmax
loop.  Grid = (B·H_kv, NSTEPS) with ``NSTEPS = NB/KB + 2``: the first
``NB/KB`` steps stream the quantized blocks (``KB ≥ 2`` quant groups per
step, so each (batch, head) DMAs wider tiles and amortizes the scale/zero
loads), the trailing 2 steps run the FP double buffer (one G-token chunk
each) through the *same* flash loop with per-position causal/validity
masking in-kernel.  The softmax state (m, l, acc) is carried in VMEM
scratch across all steps, so there is no separate FP pass, no
``[B·H, γ·g, 2G]`` mask materialization, and no log-sum-exp merge — the
App.-E combine happens implicitly in the running state.

Per quant step the kernel loads the *packed* planes:
    draft  mode: upper plane only  — 4 bits/element off HBM
    target mode: upper + lower     — 8 bits/element
and dequantizes in-register after the VMEM copy (in draft mode the lower
plane is **not an operand at all**, so its bytes never cross HBM — this is
where the paper's 2.88×/1.51× bandwidth win comes from).

Two variants share the kernel body math (`_dequant` / `_fold`):
  * `hier_flash_attention` — contiguous per-request regions
    (``[B·H, NB, …]``; KB-wide BlockSpecs along the block axis).
  * `paged_hier_flash_attention` — a global block pool addressed through a
    scalar-prefetched per-sequence block table.  Pool blocks owned by a
    sequence are scattered, so KB-wide tiles arrive as KB *lanes*: the pool
    planes are passed KB times with lane-shifted index maps and folded
    sequentially inside one grid step.

The legacy two-pass kernels (`quant_region_attention`,
`paged_quant_region_attention`) are kept at the bottom of this module as
the old-path baseline for parity tests and benchmarks; the serving paths
(`kernels/ops.py`) only call the single-pass kernels.

Validated in interpret mode against kernels/ref.py and the flat jnp
attention (tests/test_kernels.py, tests/test_paged_cache.py).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import interpret_default

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# shared kernel-body math
# ---------------------------------------------------------------------------

def _flash_init(m_scr, l_scr, acc_scr):
    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)


def _dequant(u, low, s, z, mode: str, bits=None):
    """Dequantize packed planes ``[..., G, D//2]`` → fp32 ``[..., G, D]``.

    Halves nibble layout (element j in the hi nibble of column j, element
    D/2+j in the lo nibble); ``low`` is None in draft mode.  Mode
    ``"slot"`` is the precision governor's per-slot variant: ``bits`` is
    this grid row's escalation scalar — 1 reconstructs INT8 like target
    mode, 0 zeroes the lower-plane term, which collapses *exactly* to the
    draft reconstruction (``16·q_u·(s/16) ≡ q_u·s`` in fp32; ``s/16`` is
    an exact power-of-two scale).  Non-escalated rows DMA the scratch
    block's lower plane, so whatever bytes arrive are masked here."""
    hi = (u >> 4).astype(jnp.float32)
    lo = (u & 0xF).astype(jnp.float32)
    quf = jnp.concatenate([hi, lo], axis=-1)
    s = s.astype(jnp.float32)
    z = z.astype(jnp.float32)
    if mode == "draft":
        return quf * s + z
    lhi = (low >> 4).astype(jnp.float32)
    llo = (low & 0xF).astype(jnp.float32)
    qlf = jnp.concatenate([lhi, llo], axis=-1) - 8.0
    if mode == "slot":
        qlf = jnp.where(bits > 0, qlf, 0.0)
    return (16.0 * quf + qlf) * (s / 16.0) + z


def _fold(s, v, mask, m_scr, l_scr, acc_scr):
    """Fold one score tile ``s [gT, W]`` / value tile ``v [W, D]`` into the
    online-softmax state. ``mask`` (True = attend) may be None = all valid."""
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m_prev = m_scr[...]                                # [gT, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                             # [gT, W]
    if mask is not None:
        p = jnp.where(mask, p, 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new


def _flash_out(out_ref, m_scr, l_scr, acc_scr):
    l = l_scr[...]
    out_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(out_ref.dtype)


def _plane_args(mode: str, ku, kl, ks, kz, vu, vl, vs, vz):
    """Operand order for one plane set; draft mode drops the lower planes
    so their bytes never leave HBM."""
    if mode == "draft":
        return [ku, ks, kz, vu, vs, vz]
    return [ku, kl, ks, kz, vu, vl, vs, vz]


def _unpack_lane(mode: str, lane):
    if mode == "draft":
        ku, ks, kz, vu, vs, vz = lane
        kl = vl = None
    else:
        ku, kl, ks, kz, vu, vl, vs, vz = lane
    return ku, kl, ks, kz, vu, vl, vs, vz


# ---------------------------------------------------------------------------
# single-pass contiguous kernel
# ---------------------------------------------------------------------------

def _hier_kernel(meta_ref, q_ref, *rest, mode: str, T: int, KB: int,
                 NBQ: int, G: int):
    n_planes = 6 if mode == "draft" else 8
    lane = rest[:n_planes]
    bk_ref, bv_ref, out_ref, m_scr, l_scr, acc_scr = rest[n_planes:]
    ku, kl, ks, kz, vu, vl, vs, vz = _unpack_lane(mode, lane)

    j = pl.program_id(1)
    blocks = meta_ref[0]
    buf_len = meta_ref[1]
    spos = meta_ref[2]

    @pl.when(j == 0)
    def _init():
        _flash_init(m_scr, l_scr, acc_scr)

    q = q_ref[0].astype(jnp.float32)                   # [gT, D]
    gT, D = q.shape
    inv_sqrt_d = 1.0 / math.sqrt(D)

    @pl.when((j < NBQ) & (j * KB < blocks))
    def _quant_step():
        k = _dequant(ku[0], None if kl is None else kl[0],
                     ks[0], kz[0], mode)               # [KB, G, D]
        v = _dequant(vu[0], None if vl is None else vl[0],
                     vs[0], vz[0], mode)
        k = k.reshape(KB * G, D)
        v = v.reshape(KB * G, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * inv_sqrt_d                             # [gT, KB*G]
        if KB > 1:
            grp = jax.lax.broadcasted_iota(
                jnp.int32, (gT, KB * G), 1) // G + j * KB
            mask = grp < blocks
        else:
            mask = None                                # step guard is exact
        _fold(s, v, mask, m_scr, l_scr, acc_scr)

    @pl.when((j >= NBQ) & ((j - NBQ) * G < buf_len))
    def _buffer_step():
        c = j - NBQ                                    # chunk 0 = C_F1, 1 = C_F2
        k = bk_ref[0].astype(jnp.float32)              # [G, D]
        v = bv_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * inv_sqrt_d                             # [gT, G]
        col = jax.lax.broadcasted_iota(jnp.int32, (gT, G), 1) + c * G
        row = jax.lax.broadcasted_iota(jnp.int32, (gT, G), 0)
        q_pos = spos + row % T                         # stream pos per query
        mask = (col < buf_len) & (blocks * G + col <= q_pos)
        _fold(s, v, mask, m_scr, l_scr, acc_scr)

    @pl.when(j == NBQ + 1)
    def _finalize():
        _flash_out(out_ref, m_scr, l_scr, acc_scr)


def hier_flash_attention(q, k_upper, k_lower, k_scale, k_zero,
                         v_upper, v_lower, v_scale, v_zero,
                         buf_k, buf_v, blocks, buf_len, stream_pos,
                         T: int, mode: str, *, kb: int = 2,
                         interpret: Optional[bool] = None):
    """Single-pass hierarchical attention, contiguous layout.

    q ``[BH, gT, D]`` (g = GQA replicas, T queries each, T inner); packed
    planes ``[BH, NB, G, D//2]``; k_scale/zero ``[BH, NB, 1, D]``;
    v_scale/zero ``[BH, NB, G, 1]``; FP buffer ``[BH, 2G, D]``.
    ``blocks``/``buf_len``/``stream_pos`` are (traced) i32 scalars.
    Returns out ``[BH, gT, D]`` — already softmax-normalized over the whole
    cache; no LSE leaves the kernel.
    """
    if interpret is None:
        interpret = interpret_default()
    BH, gT, D = q.shape
    NB, G = k_upper.shape[1], k_upper.shape[2]
    Dp = D // 2
    assert NB >= 1, "hierarchical cache needs ≥ 1 quant block of capacity"
    assert buf_k.shape[1] == 2 * G, (buf_k.shape, G)
    KB = kb if kb >= 1 and NB % kb == 0 else 1
    NBQ = NB // KB
    nsteps = NBQ + 2

    ks = jnp.broadcast_to(k_scale, (BH, NB, 1, D))
    kz = jnp.broadcast_to(k_zero, (BH, NB, 1, D))
    vs = jnp.broadcast_to(v_scale, (BH, NB, G, 1))
    vz = jnp.broadcast_to(v_zero, (BH, NB, G, 1))

    meta = jnp.stack([jnp.asarray(blocks, jnp.int32).reshape(()),
                      jnp.asarray(buf_len, jnp.int32).reshape(()),
                      jnp.asarray(stream_pos, jnp.int32).reshape(())])

    # index maps get the scalar-prefetch ref after the grid indices; quant
    # plane blocks clamp to the last KB-chunk during buffer steps (masked
    # out by the kernel), buffer blocks clamp to chunk 0 during quant steps.
    qspec = pl.BlockSpec((1, gT, D), lambda i, j, m: (i, 0, 0))
    pmap = lambda i, j, m: (i, jnp.minimum(j, NBQ - 1), 0, 0)
    pspec = pl.BlockSpec((1, KB, G, Dp), pmap)
    ksspec = pl.BlockSpec((1, KB, 1, D), pmap)
    vsspec = pl.BlockSpec((1, KB, G, 1), pmap)
    bmap = lambda i, j, m: (i, jnp.clip(j - NBQ, 0, 1), 0)
    bspec = pl.BlockSpec((1, G, D), bmap)

    in_specs = [qspec] + _plane_args(mode, pspec, pspec, ksspec, ksspec,
                                     pspec, pspec, vsspec, vsspec) \
        + [bspec, bspec]
    args = [q] + _plane_args(mode, k_upper, k_lower, ks, kz,
                             v_upper, v_lower, vs, vz) + [buf_k, buf_v]

    out = pl.pallas_call(
        functools.partial(_hier_kernel, mode=mode, T=T, KB=KB, NBQ=NBQ, G=G),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(BH, nsteps),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, gT, D), lambda i, j, m: (i, 0, 0)),
            scratch_shapes=[pltpu.VMEM((gT, 1), jnp.float32),
                            pltpu.VMEM((gT, 1), jnp.float32),
                            pltpu.VMEM((gT, D), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((BH, gT, D), q.dtype),
        interpret=interpret,
    )(meta, *args)
    return out


# ---------------------------------------------------------------------------
# single-pass paged kernel
# ---------------------------------------------------------------------------

def _paged_hier_kernel(meta_ref, bt_ref, q_ref, *rest, mode: str, T: int,
                       KB: int, NBQ: int, G: int, nh: int):
    """Block-table single-pass flash decoding: grid (R·H, NBQ + 2).

    ``bt_ref`` is consumed by the index maps only.  KB quant groups arrive
    per step as KB lane-shifted copies of the pool planes; each lane folds
    one group when its group index is in range (exact per-lane guard, so no
    column mask is needed for the quantized region).

    Mode ``"slot"`` (the precision governor's escalated-draft variant)
    carries the 8-operand plane set of target mode but selects per grid
    row: ``meta[r, 3]`` gates the lower-plane term inside `_dequant`, and
    the lower-plane index maps routed non-escalated rows' DMA to the pool
    scratch block — those rows stream 4 bits/element plus one reused
    scratch tile, so the draft-mode bandwidth win survives a mixed
    batch."""
    del bt_ref
    n_planes = 6 if mode == "draft" else 8
    lanes = [rest[l * n_planes:(l + 1) * n_planes] for l in range(KB)]
    bk_ref, bv_ref, out_ref, m_scr, l_scr, acc_scr = rest[KB * n_planes:]

    i = pl.program_id(0)
    j = pl.program_id(1)
    r = i // nh
    blocks = meta_ref[r, 0]
    buf_len = meta_ref[r, 1]
    spos = meta_ref[r, 2]
    bits = meta_ref[r, 3] if mode == "slot" else None

    @pl.when(j == 0)
    def _init():
        _flash_init(m_scr, l_scr, acc_scr)

    q = q_ref[0].astype(jnp.float32)                   # [gT, D]
    gT, D = q.shape
    inv_sqrt_d = 1.0 / math.sqrt(D)

    for lidx in range(KB):
        ku, kl, ks, kz, vu, vl, vs, vz = _unpack_lane(mode, lanes[lidx])

        def _lane_step(ku=ku, kl=kl, ks=ks, kz=kz,
                       vu=vu, vl=vl, vs=vs, vz=vz):
            k = _dequant(ku[0], None if kl is None else kl[0],
                         ks[0], kz[0], mode, bits)     # [G, D]
            v = _dequant(vu[0], None if vl is None else vl[0],
                         vs[0], vz[0], mode, bits)
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            _fold(s * inv_sqrt_d, v, None, m_scr, l_scr, acc_scr)

        pl.when((j < NBQ) & (j * KB + lidx < blocks))(_lane_step)

    @pl.when((j >= NBQ) & ((j - NBQ) * G < buf_len))
    def _buffer_step():
        c = j - NBQ
        k = bk_ref[0].astype(jnp.float32)              # [G, D]
        v = bv_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * inv_sqrt_d
        col = jax.lax.broadcasted_iota(jnp.int32, (gT, G), 1) + c * G
        row = jax.lax.broadcasted_iota(jnp.int32, (gT, G), 0)
        q_pos = spos + row % T
        mask = (col < buf_len) & (blocks * G + col <= q_pos)
        _fold(s, v, mask, m_scr, l_scr, acc_scr)

    @pl.when(j == NBQ + 1)
    def _finalize():
        _flash_out(out_ref, m_scr, l_scr, acc_scr)


def paged_hier_flash_attention(q, k_upper, k_lower, k_scale, k_zero,
                               v_upper, v_lower, v_scale, v_zero,
                               buf_k, buf_v, block_table, blocks, buf_len,
                               stream_pos, nh: int, T: int, mode: str, *,
                               kb: int = 2, draft_bits=None,
                               interpret: Optional[bool] = None):
    """Single-pass hierarchical attention over a **paged** pool.

    q ``[R*H, gT, D]``; pool planes flattened per (block, head):
    ``k/v_upper/lower [(P+1)*H, G, D//2]``, ``k_scale/zero [(P+1)*H, 1, D]``,
    ``v_scale/zero [(P+1)*H, G, 1]`` (row ``p*H + h`` = head ``h`` of pool
    block ``p``); per-slot FP buffers ``[R*H, 2G, D]``.  ``block_table
    [R, NBmax]`` plus per-slot ``blocks``/``buf_len``/``stream_pos [R]`` are
    scalar-prefetched; the BlockSpec index maps dereference the table so
    each lane DMAs exactly the pool block the sequence owns — the gather
    never materializes.  Returns out ``[R*H, gT, D]``.

    ``draft_bits`` (i32/bool ``[R]``, draft mode only) switches the call
    into the governor's ``"slot"`` variant: escalated slots read both
    nibble planes (INT8), while non-escalated slots' lower-plane index
    maps resolve to the pool's write-scratch block ``P`` — a single
    always-resident tile instead of per-block lower-plane traffic — and
    the garbage is zero-masked in-kernel, reproducing the draft
    reconstruction bit for bit.
    """
    if interpret is None:
        interpret = interpret_default()
    RH, gT, D = q.shape
    R, NBmax = block_table.shape
    G = k_upper.shape[1]
    Dp = D // 2
    assert buf_k.shape[1] == 2 * G, (buf_k.shape, G)
    KB = max(1, min(kb, NBmax))
    NBQ = -(-NBmax // KB)                              # ceil
    nsteps = NBQ + 2
    if mode == "draft" and draft_bits is not None:
        mode = "slot"
    scratch_blk = k_upper.shape[0] // nh - 1           # pool block P

    ks = jnp.broadcast_to(k_scale, (k_upper.shape[0], 1, D))
    kz = jnp.broadcast_to(k_zero, (k_upper.shape[0], 1, D))
    vs = jnp.broadcast_to(v_scale, (k_upper.shape[0], G, 1))
    vz = jnp.broadcast_to(v_zero, (k_upper.shape[0], G, 1))

    bits = jnp.zeros((R,), jnp.int32) if draft_bits is None \
        else jnp.asarray(draft_bits, jnp.int32)
    meta = jnp.stack([jnp.asarray(blocks, jnp.int32),
                      jnp.asarray(buf_len, jnp.int32),
                      jnp.asarray(stream_pos, jnp.int32),
                      bits], axis=1)                   # [R, 4]

    qspec = pl.BlockSpec((1, gT, D), lambda i, j, m, bt: (i, 0, 0))

    def lane_map(l):
        def f(i, j, m, bt):
            col = jnp.minimum(j * KB + l, NBmax - 1)
            return (bt[i // nh, col] * nh + i % nh, 0, 0)
        return f

    def lane_map_lower(l):
        # slot mode: non-escalated rows DMA the scratch block's lower
        # plane (always resident, masked in-kernel) instead of the real
        # one — their lower-plane bytes never cross HBM per block
        def f(i, j, m, bt):
            r = i // nh
            col = jnp.minimum(j * KB + l, NBmax - 1)
            blk = jnp.where(m[r, 3] > 0, bt[r, col], scratch_blk)
            return (blk * nh + i % nh, 0, 0)
        return f

    lane_specs = []
    lane_args = []
    for l in range(KB):
        pspec = pl.BlockSpec((1, G, Dp), lane_map(l))
        lspec = pl.BlockSpec((1, G, Dp), lane_map_lower(l)) \
            if mode == "slot" else pspec
        ksspec = pl.BlockSpec((1, 1, D), lane_map(l))
        vsspec = pl.BlockSpec((1, G, 1), lane_map(l))
        lane_specs += _plane_args(mode, pspec, lspec, ksspec, ksspec,
                                  pspec, lspec, vsspec, vsspec)
        lane_args += _plane_args(mode, k_upper, k_lower, ks, kz,
                                 v_upper, v_lower, vs, vz)

    bspec = pl.BlockSpec((1, G, D),
                         lambda i, j, m, bt: (i, jnp.clip(j - NBQ, 0, 1), 0))

    out = pl.pallas_call(
        functools.partial(_paged_hier_kernel, mode=mode, T=T, KB=KB,
                          NBQ=NBQ, G=G, nh=nh),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(RH, nsteps),
            in_specs=[qspec] + lane_specs + [bspec, bspec],
            out_specs=pl.BlockSpec((1, gT, D), lambda i, j, m, bt: (i, 0, 0)),
            scratch_shapes=[pltpu.VMEM((gT, 1), jnp.float32),
                            pltpu.VMEM((gT, 1), jnp.float32),
                            pltpu.VMEM((gT, D), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((RH, gT, D), q.dtype),
        interpret=interpret,
    )(meta, jnp.asarray(block_table, jnp.int32), q, *lane_args, buf_k, buf_v)
    return out


# ---------------------------------------------------------------------------
# legacy two-pass kernels (quantized region only, LSE out) — kept as the
# old-path baseline for parity tests and benchmarks; not used for serving.
# ---------------------------------------------------------------------------

def _flash_block_update(q_ref, ku_ref, kl_ref, ks_ref, kz_ref,
                        vu_ref, vl_ref, vs_ref, vz_ref,
                        m_scr, l_scr, acc_scr, *, mode: str, ix: tuple):
    """Dequantize one KV block and fold it into the online-softmax state."""
    q = q_ref[0].astype(jnp.float32)                  # [gT, D]
    D = q.shape[-1]
    k = _dequant(ku_ref[ix], kl_ref[ix], ks_ref[ix], kz_ref[ix], mode)
    v = _dequant(vu_ref[ix], vl_ref[ix], vs_ref[ix], vz_ref[ix], mode)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    _fold(s / math.sqrt(D), v, None, m_scr, l_scr, acc_scr)


def _flash_finalize(out_ref, lse_ref, m_scr, l_scr, acc_scr):
    l = l_scr[...]
    out_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(out_ref.dtype)
    lse = jnp.where(l > 0, m_scr[...] + jnp.log(jnp.maximum(l, 1e-30)),
                    -jnp.inf)
    lse_ref[0] = lse[:, 0]


def _kernel(blocks_ref,                      # scalar prefetch: [1] i32
            q_ref, ku_ref, kl_ref, ks_ref, kz_ref,
            vu_ref, vl_ref, vs_ref, vz_ref,
            out_ref, lse_ref,
            m_scr, l_scr, acc_scr,
            *, mode: str, nb_total: int):
    nb = pl.program_id(1)

    @pl.when(nb == 0)
    def _init():
        _flash_init(m_scr, l_scr, acc_scr)

    @pl.when(nb < blocks_ref[0])
    def _process():
        _flash_block_update(q_ref, ku_ref, kl_ref, ks_ref, kz_ref,
                            vu_ref, vl_ref, vs_ref, vz_ref,
                            m_scr, l_scr, acc_scr, mode=mode, ix=(0, 0))

    @pl.when(nb == nb_total - 1)
    def _finalize():
        _flash_finalize(out_ref, lse_ref, m_scr, l_scr, acc_scr)


def _paged_kernel(blocks_ref,                 # scalar prefetch: [R] i32
                  bt_ref,                     # scalar prefetch: [R, NBmax] i32
                  q_ref, ku_ref, kl_ref, ks_ref, kz_ref,
                  vu_ref, vl_ref, vs_ref, vz_ref,
                  out_ref, lse_ref,
                  m_scr, l_scr, acc_scr,
                  *, mode: str, nb_total: int, nh: int):
    del bt_ref
    i = pl.program_id(0)
    nb = pl.program_id(1)
    r = i // nh

    @pl.when(nb == 0)
    def _init():
        _flash_init(m_scr, l_scr, acc_scr)

    @pl.when(nb < blocks_ref[r])
    def _process():
        _flash_block_update(q_ref, ku_ref, kl_ref, ks_ref, kz_ref,
                            vu_ref, vl_ref, vs_ref, vz_ref,
                            m_scr, l_scr, acc_scr, mode=mode, ix=(0,))

    @pl.when(nb == nb_total - 1)
    def _finalize():
        _flash_finalize(out_ref, lse_ref, m_scr, l_scr, acc_scr)


def paged_quant_region_attention(q, k_upper, k_lower, k_scale, k_zero,
                                 v_upper, v_lower, v_scale, v_zero,
                                 block_table, blocks, nh: int, mode: str, *,
                                 interpret: Optional[bool] = None):
    """Legacy two-pass flash decoding over a **paged** quantized region
    (no FP buffer; returns ``(out, lse)`` for an external merge)."""
    if interpret is None:
        interpret = interpret_default()
    RH, gT, D = q.shape
    NBmax = block_table.shape[1]
    G = k_upper.shape[1]
    Dp = D // 2

    ks = jnp.broadcast_to(k_scale, (k_upper.shape[0], 1, D))
    kz = jnp.broadcast_to(k_zero, (k_upper.shape[0], 1, D))
    vs = jnp.broadcast_to(v_scale, (k_upper.shape[0], G, 1))
    vz = jnp.broadcast_to(v_zero, (k_upper.shape[0], G, 1))

    grid = (RH, NBmax)
    # index maps receive the two scalar-prefetch refs after the grid indices
    def page(i, j, blk, bt):
        return (bt[i // nh, j] * nh + i % nh, 0, 0)

    qspec = pl.BlockSpec((1, gT, D), lambda i, j, blk, bt: (i, 0, 0))
    pspec = pl.BlockSpec((1, G, Dp), page)
    ksspec = pl.BlockSpec((1, 1, D), page)
    vsspec = pl.BlockSpec((1, G, 1), page)

    out, lse = pl.pallas_call(
        functools.partial(_paged_kernel, mode=mode, nb_total=NBmax, nh=nh),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[qspec, pspec, pspec, ksspec, ksspec,
                      pspec, pspec, vsspec, vsspec],
            out_specs=[
                pl.BlockSpec((1, gT, D), lambda i, j, blk, bt: (i, 0, 0)),
                pl.BlockSpec((1, gT), lambda i, j, blk, bt: (i, 0))],
            scratch_shapes=[pltpu.VMEM((gT, 1), jnp.float32),
                            pltpu.VMEM((gT, 1), jnp.float32),
                            pltpu.VMEM((gT, D), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((RH, gT, D), q.dtype),
                   jax.ShapeDtypeStruct((RH, gT), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(blocks, jnp.int32), jnp.asarray(block_table, jnp.int32),
      q, k_upper, k_lower, ks, kz, v_upper, v_lower, vs, vz)
    return out, lse


def quant_region_attention(q, k_upper, k_lower, k_scale, k_zero,
                           v_upper, v_lower, v_scale, v_zero,
                           blocks, mode: str, *, interpret: Optional[bool] = None):
    """Legacy two-pass kernel: q [BH, gT, D]; packed planes
    [BH, NB, G, D//2]; k_scale/zero [BH, NB, 1, D]; v_scale/zero
    [BH, NB, G, 1]. Returns (out [BH, gT, D], lse [BH, gT])."""
    if interpret is None:
        interpret = interpret_default()
    BH, gT, D = q.shape
    NB, G = k_upper.shape[1], k_upper.shape[2]
    Dp = D // 2

    # broadcast scale layouts the kernel expects: [BH, NB, G|1, D]
    ks = jnp.broadcast_to(k_scale, (BH, NB, 1, D))
    kz = jnp.broadcast_to(k_zero, (BH, NB, 1, D))
    vs = jnp.broadcast_to(v_scale, (BH, NB, G, 1))
    vz = jnp.broadcast_to(v_zero, (BH, NB, G, 1))

    grid = (BH, NB)
    # index maps take a trailing ref arg for the scalar-prefetch operand
    qspec = pl.BlockSpec((1, gT, D), lambda i, j, s: (i, 0, 0))
    pspec = pl.BlockSpec((1, 1, G, Dp), lambda i, j, s: (i, j, 0, 0))
    ksspec = pl.BlockSpec((1, 1, 1, D), lambda i, j, s: (i, j, 0, 0))
    vsspec = pl.BlockSpec((1, 1, G, 1), lambda i, j, s: (i, j, 0, 0))

    out, lse = pl.pallas_call(
        functools.partial(_kernel, mode=mode, nb_total=NB),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[qspec, pspec, pspec, ksspec, ksspec,
                      pspec, pspec, vsspec, vsspec],
            out_specs=[pl.BlockSpec((1, gT, D), lambda i, j, s: (i, 0, 0)),
                       pl.BlockSpec((1, gT), lambda i, j, s: (i, 0))],
            scratch_shapes=[pltpu.VMEM((gT, 1), jnp.float32),
                            pltpu.VMEM((gT, 1), jnp.float32),
                            pltpu.VMEM((gT, D), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((BH, gT, D), q.dtype),
                   jax.ShapeDtypeStruct((BH, gT), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(blocks, jnp.int32).reshape(1), q,
      k_upper, k_lower, ks, kz, v_upper, v_lower, vs, vz)
    return out, lse
