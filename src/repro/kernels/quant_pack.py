"""Pallas TPU kernel: hierarchical quantize+pack of one KV group block.

Runs at every buffer flush (once per G accepted tokens) and over all blocks
at prefill. Grid = (B·H_kv,); each step quantizes a [G, D] tile held in
VMEM: keys per-channel (reduce over tokens), values per-token (reduce over
head_dim), emitting both nibble-packed INT4 planes plus fp32 scale/zero.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import interpret_default

_EPS = 1e-8


def _quant_hier(x, axis):
    mn = jnp.min(x, axis=axis, keepdims=True)
    mx = jnp.max(x, axis=axis, keepdims=True)
    s4 = jnp.maximum((mx - mn) / 15.0, _EPS)
    qu = jnp.clip(jnp.round((x - mn) / s4), 0.0, 15.0)
    err = x - (qu * s4 + mn)
    ql = jnp.clip(jnp.round(err / (s4 / 16.0)), -8.0, 7.0) + 8.0
    return qu, ql, s4, mn


def _pack(q):  # [G, D] float of ints -> [G, D//2] uint8, halves layout
    D = q.shape[-1]
    qi = q.astype(jnp.uint8)
    return (qi[:, : D // 2] << 4) | qi[:, D // 2:]


def _kernel(k_ref, v_ref,
            ku_ref, kl_ref, ks_ref, kz_ref,
            vu_ref, vl_ref, vs_ref, vz_ref):
    k = k_ref[0].astype(jnp.float32)   # [G, D]
    v = v_ref[0].astype(jnp.float32)

    qu, ql, s, z = _quant_hier(k, axis=0)     # keys: per-channel
    ku_ref[0] = _pack(qu)
    kl_ref[0] = _pack(ql)
    ks_ref[0] = s
    kz_ref[0] = z

    qu, ql, s, z = _quant_hier(v, axis=1)     # values: per-token
    vu_ref[0] = _pack(qu)
    vl_ref[0] = _pack(ql)
    vs_ref[0] = s
    vz_ref[0] = z


def quantize_kv_block(k, v, *, interpret: Optional[bool] = None):
    """k, v [BH, G, D] -> dict of packed planes + scales (see ref.py)."""
    if interpret is None:
        interpret = interpret_default()
    BH, G, D = k.shape
    Dp = D // 2
    spec_in = pl.BlockSpec((1, G, D), lambda i: (i, 0, 0))
    outs = pl.pallas_call(
        _kernel,
        grid=(BH,),
        in_specs=[spec_in, spec_in],
        out_specs=[
            pl.BlockSpec((1, G, Dp), lambda i: (i, 0, 0)),  # ku
            pl.BlockSpec((1, G, Dp), lambda i: (i, 0, 0)),  # kl
            pl.BlockSpec((1, 1, D), lambda i: (i, 0, 0)),   # ks
            pl.BlockSpec((1, 1, D), lambda i: (i, 0, 0)),   # kz
            pl.BlockSpec((1, G, Dp), lambda i: (i, 0, 0)),  # vu
            pl.BlockSpec((1, G, Dp), lambda i: (i, 0, 0)),  # vl
            pl.BlockSpec((1, G, 1), lambda i: (i, 0, 0)),   # vs
            pl.BlockSpec((1, G, 1), lambda i: (i, 0, 0)),   # vz
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, G, Dp), jnp.uint8),
            jax.ShapeDtypeStruct((BH, G, Dp), jnp.uint8),
            jax.ShapeDtypeStruct((BH, 1, D), jnp.float32),
            jax.ShapeDtypeStruct((BH, 1, D), jnp.float32),
            jax.ShapeDtypeStruct((BH, G, Dp), jnp.uint8),
            jax.ShapeDtypeStruct((BH, G, Dp), jnp.uint8),
            jax.ShapeDtypeStruct((BH, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((BH, G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(k, v)
    keys = ("k_upper", "k_lower", "k_scale", "k_zero",
            "v_upper", "v_lower", "v_scale", "v_zero")
    return dict(zip(keys, outs))
