"""jit'd wrappers tying the Pallas kernels to the cache/model layer.

`hier_attention` implements the same contract as
`models.common.attend_hier` (impl="pallas"): one single-pass Pallas flash
kernel over the *entire* hierarchical cache — quantized region + FP recent
buffer — with the buffer handled as trailing grid steps of the same online
softmax (no second jnp pass, no materialized ``[B·H, γ·g, 2G]`` mask, no
log-sum-exp merge).

`paged_hier_attention` is the block-table analogue over a
`core.paged_kv_cache` pool: the kernel gathers each sequence's pool blocks
through a scalar-prefetched block table and folds the per-slot FP buffers
in the same pass (per-slot stream positions — continuous batching is
ragged).
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.hier_kv_cache import HierKVCache
from repro.core.paged_kv_cache import PagedKVPool, PageTable
from repro.kernels.prefill_attention import flash_prefill_attention
from repro.kernels.quant_attention import (
    hier_flash_attention,
    paged_hier_flash_attention,
)


def _bh(x):
    """[B, NB, G, H, X] -> [B*H, NB, G, X]"""
    B, NB, G, H, X = x.shape
    return x.transpose(0, 3, 1, 2, 4).reshape(B * H, NB, G, X)


def hier_attention(q, cache: HierKVCache, stream_pos, mode: str,
                   softcap: float = 0.0, interpret: Optional[bool] = None):
    """q [B, T, Hq, D] over a hierarchical cache (post-append).

    Draft mode streams 4 bits/KV element through the kernel (the lower
    plane is not an operand), target mode 8 — the QuantSpec bandwidth win.
    Softcap is not fused (only needed by archs with softcap=0 here)."""
    if softcap != 0.0:
        raise NotImplementedError("softcap not fused in the Pallas kernel")
    B, T, Hq, D = q.shape
    H = cache.buf_k.shape[2]
    g = Hq // H
    G = cache.group

    qr = q.reshape(B, T, H, g, D).transpose(0, 2, 3, 1, 4)  # [B,H,g,T,D]
    qr = qr.reshape(B * H, g * T, D)
    buf_k = cache.buf_k.transpose(0, 2, 1, 3).reshape(B * H, 2 * G, D)
    buf_v = cache.buf_v.transpose(0, 2, 1, 3).reshape(B * H, 2 * G, D)

    out = hier_flash_attention(
        qr,
        _bh(cache.k_upper), _bh(cache.k_lower),
        _bh(cache.k_scale), _bh(cache.k_zero),
        _bh(cache.v_upper), _bh(cache.v_lower),
        _bh(cache.v_scale), _bh(cache.v_zero),
        buf_k, buf_v,
        cache.blocks, cache.buf_len, stream_pos, T, mode,
        interpret=interpret)                                  # [BH, gT, D]

    out = out.reshape(B, H, g, T, D).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, T, Hq, D)


def _pool_bh(x):
    """[P1, G|1, H, X] -> [P1*H, G|1, X] (row p*H + h)."""
    P1, G, H, X = x.shape
    return x.transpose(0, 2, 1, 3).reshape(P1 * H, G, X)


def paged_hier_attention(q, pool: PagedKVPool, table: PageTable, stream_pos,
                         mode: str, softcap: float = 0.0,
                         interpret: Optional[bool] = None):
    """q [R, T, Hq, D] over a paged hierarchical cache (post-`apply_step`).

    `stream_pos` is per-slot [R] — the stream position of each slot's first
    query token (requests progress raggedly under continuous batching).
    Quantized pool blocks and each slot's FP buffer stream through one
    single-pass block-table kernel."""
    if softcap != 0.0:
        raise NotImplementedError("softcap not fused in the Pallas kernel")
    R, T, Hq, D = q.shape
    H = pool.buf_k.shape[2]
    g = Hq // H
    G = pool.group

    qr = q.reshape(R, T, H, g, D).transpose(0, 2, 3, 1, 4)   # [R,H,g,T,D]
    qr = qr.reshape(R * H, g * T, D)
    buf_k = pool.buf_k.transpose(0, 2, 1, 3).reshape(R * H, 2 * G, D)
    buf_v = pool.buf_v.transpose(0, 2, 1, 3).reshape(R * H, 2 * G, D)

    out = paged_hier_flash_attention(
        qr,
        _pool_bh(pool.k_upper), _pool_bh(pool.k_lower),
        _pool_bh(pool.k_scale), _pool_bh(pool.k_zero),
        _pool_bh(pool.v_upper), _pool_bh(pool.v_lower),
        _pool_bh(pool.v_scale), _pool_bh(pool.v_zero),
        buf_k, buf_v,
        table.block_table, table.blocks, table.buf_len,
        jnp.asarray(stream_pos, jnp.int32), H, T, mode,
        interpret=interpret)                                  # [RH, gT, D]

    out = out.reshape(R, H, g, T, D).transpose(0, 3, 1, 2, 4)
    return out.reshape(R, T, Hq, D)


def prefill_attention(q, k, v, q_start, kv_len, softcap: float = 0.0,
                      interpret: Optional[bool] = None):
    """Causal flash-prefill attention (serve-time prefill fast path).

    q ``[B, T, Hq, D]`` are the chunk's queries at stream positions
    ``q_start + [0, T)``; k/v ``[B, S, Hkv, D]`` hold the full key stream
    (prompt-so-far + chunk), of which the first ``kv_len`` positions are
    valid.  One-shot padded prefill is the ``q_start = 0, kv_len = L``
    special case; a mid-prompt chunk is the rectangular causal band
    ``q_start > 0``.  GQA folds the g query replicas into the row axis of
    the same ``[B·Hkv, g·T, D]`` layout the decode kernels use, so each KV
    tile is DMA'd once per kv-head."""
    if softcap != 0.0:
        raise NotImplementedError("softcap not fused in the Pallas kernel")
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv

    qr = q.reshape(B, T, Hkv, g, D).transpose(0, 2, 3, 1, 4)  # [B,H,g,T,D]
    qr = qr.reshape(B * Hkv, g * T, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, k.shape[1], D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, v.shape[1], D)

    out = flash_prefill_attention(qr, kr, vr, q_start, kv_len, T,
                                  interpret=interpret)        # [BH, gT, D]
    out = out.reshape(B, Hkv, g, T, D).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, T, Hq, D)
