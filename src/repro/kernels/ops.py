"""jit'd wrappers tying the Pallas kernels to the cache/model layer.

`hier_attention` implements the same contract as
`models.common.attend_hier` (impl="pallas"): one single-pass Pallas flash
kernel over the *entire* hierarchical cache — quantized region + FP recent
buffer — with the buffer handled as trailing grid steps of the same online
softmax (no second jnp pass, no materialized ``[B·H, γ·g, 2G]`` mask, no
log-sum-exp merge).

`paged_hier_attention` is the block-table analogue over a
`core.paged_kv_cache` pool: the kernel gathers each sequence's pool blocks
through a scalar-prefetched block table and folds the per-slot FP buffers
in the same pass (per-slot stream positions — continuous batching is
ragged).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.hier_kv_cache import HierKVCache
from repro.core.paged_kv_cache import PagedKVPool, PageTable
from repro.distributed.sharding import current_mesh, data_parallel_size, model_parallel_size
from repro.kernels.prefill_attention import flash_prefill_attention
from repro.kernels.quant_attention import hier_flash_attention, paged_hier_flash_attention


# ---------------------------------------------------------------------------
# tensor-parallel entry: Pallas kernels under a `model`-sharded mesh
# ---------------------------------------------------------------------------
# A pallas_call inside a jitted SPMD program would force XLA to gather its
# operands; instead each wrapper below has a shard_map entry over the mesh
# that slices the kv-head axis across `model` (and, when divisible, the
# batch/slot axis across `data`) and runs the unchanged kernel on each
# shard's local heads. Heads stay local — attention needs no collective at
# all; the reduction over heads happens later in the (sharded) `wo` matmul.

def _shard_map(fn, mesh, in_specs, out_specs):
    try:
        from jax import shard_map  # jax >= 0.5
    except ImportError:
        from jax.experimental.shard_map import shard_map
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    try:
        return shard_map(fn, check_vma=False, **kw)
    except TypeError:  # older jax: the kwarg is check_rep
        return shard_map(fn, check_rep=False, **kw)


def _head_shard_ctx(Hkv: int, Hq: int, batch: int):
    """(mesh, batch_axis) when the active mesh can head-shard this call:
    the `model` extent must divide both head counts; the `data` axis rides
    along on the batch/slot dim only when it divides."""
    mesh = current_mesh()
    m = model_parallel_size(mesh)
    if mesh is None or m <= 1 or Hkv % m or Hq % m:
        return None, None
    d = data_parallel_size(mesh)
    b_ax = "data" if d > 1 and batch % d == 0 else None
    return mesh, b_ax


def _bh(x):
    """[B, NB, G, H, X] -> [B*H, NB, G, X]"""
    B, NB, G, H, X = x.shape
    return x.transpose(0, 3, 1, 2, 4).reshape(B * H, NB, G, X)


def hier_attention(q, cache: HierKVCache, stream_pos, mode: str,
                   softcap: float = 0.0, interpret: Optional[bool] = None):
    """q [B, T, Hq, D] over a hierarchical cache (post-append).

    Draft mode streams 4 bits/KV element through the kernel (the lower
    plane is not an operand), target mode 8 — the QuantSpec bandwidth win.
    Softcap is not fused (only needed by archs with softcap=0 here)."""
    if softcap != 0.0:
        raise NotImplementedError("softcap not fused in the Pallas kernel")
    B, T, Hq, D = q.shape
    H = cache.buf_k.shape[2]
    G = cache.group

    def run(q, cache, stream_pos):
        Bl = q.shape[0]                    # batch rows local to this shard
        Hl = cache.buf_k.shape[2]          # heads local to this shard
        gl = q.shape[2] // Hl
        qr = q.reshape(Bl, T, Hl, gl, D).transpose(0, 2, 3, 1, 4)
        qr = qr.reshape(Bl * Hl, gl * T, D)
        buf_k = cache.buf_k.transpose(0, 2, 1, 3).reshape(Bl * Hl, 2 * G, D)
        buf_v = cache.buf_v.transpose(0, 2, 1, 3).reshape(Bl * Hl, 2 * G, D)
        out = hier_flash_attention(
            qr,
            _bh(cache.k_upper), _bh(cache.k_lower),
            _bh(cache.k_scale), _bh(cache.k_zero),
            _bh(cache.v_upper), _bh(cache.v_lower),
            _bh(cache.v_scale), _bh(cache.v_zero),
            buf_k, buf_v,
            cache.blocks, cache.buf_len, stream_pos, T, mode,
            interpret=interpret)                              # [BHl, gT, D]
        out = out.reshape(Bl, Hl, gl, T, D).transpose(0, 3, 1, 2, 4)
        return out.reshape(Bl, T, Hl * gl, D)

    mesh, b = _head_shard_ctx(H, Hq, B)
    if mesh is None:
        return run(q, cache, stream_pos)
    plane = P(b, None, None, "model", None)    # [B, NB, G|1, H, X]
    cache_specs = HierKVCache(
        k_upper=plane, k_lower=plane, k_scale=plane, k_zero=plane,
        v_upper=plane, v_lower=plane, v_scale=plane, v_zero=plane,
        blocks=P(), buf_k=P(b, None, "model", None),
        buf_v=P(b, None, "model", None), buf_len=P())  # lockstep scalars
    qspec = P(b, None, "model", None)
    return _shard_map(run, mesh, (qspec, cache_specs, P()), qspec)(
        q, cache, jnp.asarray(stream_pos, jnp.int32))


def _pool_bh(x):
    """[P1, G|1, H, X] -> [P1*H, G|1, X] (row p*H + h)."""
    P1, G, H, X = x.shape
    return x.transpose(0, 2, 1, 3).reshape(P1 * H, G, X)


def paged_hier_attention(q, pool: PagedKVPool, table: PageTable, stream_pos,
                         mode: str, softcap: float = 0.0,
                         interpret: Optional[bool] = None, draft_bits=None):
    """q [R, T, Hq, D] over a paged hierarchical cache (post-`apply_step`).

    `stream_pos` is per-slot [R] — the stream position of each slot's first
    query token (requests progress raggedly under continuous batching).
    Quantized pool blocks and each slot's FP buffer stream through one
    single-pass block-table kernel.  ``draft_bits`` (bool [R], draft mode)
    is the precision governor's per-slot INT8-escalation flag, forwarded
    to the kernel's ``"slot"`` variant."""
    if softcap != 0.0:
        raise NotImplementedError("softcap not fused in the Pallas kernel")
    R, T, Hq, D = q.shape
    H = pool.kv_heads
    G = pool.group
    if mode != "draft":
        draft_bits = None

    def run(q, pool, block_table, blocks, buf_len, stream_pos, bits):
        Rl = q.shape[0]                    # slots local to this shard
        Hl = pool.buf_k.shape[2]           # heads local to this shard
        gl = q.shape[2] // Hl
        qr = q.reshape(Rl, T, Hl, gl, D).transpose(0, 2, 3, 1, 4)
        qr = qr.reshape(Rl * Hl, gl * T, D)
        buf_k = pool.buf_k.transpose(0, 2, 1, 3).reshape(Rl * Hl, 2 * G, D)
        buf_v = pool.buf_v.transpose(0, 2, 1, 3).reshape(Rl * Hl, 2 * G, D)
        out = paged_hier_flash_attention(
            qr,
            _pool_bh(pool.k_upper), _pool_bh(pool.k_lower),
            _pool_bh(pool.k_scale), _pool_bh(pool.k_zero),
            _pool_bh(pool.v_upper), _pool_bh(pool.v_lower),
            _pool_bh(pool.v_scale), _pool_bh(pool.v_zero),
            buf_k, buf_v,
            block_table, blocks, buf_len, stream_pos, Hl, T, mode,
            draft_bits=None if draft_bits is None else bits,
            interpret=interpret)                              # [RHl, gT, D]
        out = out.reshape(Rl, Hl, gl, T, D).transpose(0, 3, 1, 2, 4)
        return out.reshape(Rl, T, Hl * gl, D)

    bits = jnp.zeros((R,), jnp.int32) if draft_bits is None \
        else jnp.asarray(draft_bits, jnp.int32)
    args = (q, pool, table.block_table, table.blocks, table.buf_len,
            jnp.asarray(stream_pos, jnp.int32), bits)
    mesh, d = _head_shard_ctx(H, Hq, R)
    if mesh is None:
        return run(*args)
    plane = P(None, None, "model", None)       # [P+1, G|1, H, X] shared pool
    pool_specs = PagedKVPool(
        k_upper=plane, k_lower=plane, k_scale=plane, k_zero=plane,
        v_upper=plane, v_lower=plane, v_scale=plane, v_zero=plane,
        buf_k=P(d, None, "model", None), buf_v=P(d, None, "model", None))
    qspec = P(d, None, "model", None)
    in_specs = (qspec, pool_specs, P(d, None), P(d), P(d), P(d), P(d))
    return _shard_map(run, mesh, in_specs, qspec)(*args)


def int4_matmul_tp(x, w, role: str):
    """Fused INT4 dequant×matmul under a tensor-parallel mesh: a shard_map
    entry that runs the unchanged Pallas kernel (kernels/quant_matmul.py)
    on each `model` shard's local slice of the packed planes, instead of
    bypassing to the sharded dequant+dot.

    ``role`` is the weight's serve-mode matrix role at this call site:

    ``"col"``  column-parallel (wq/wk/wv/w_gate/w_up/lm_head) — the out
               dim ``d_out`` is sharded over `model`, activations enter
               replicated across `model`, each shard computes its output
               columns, no collective (downstream constrains re-anchor).
    ``"row"``  row-parallel (wo/w_down) — the in dim is sharded over
               `model` (the ``d_in//group`` axis of the packed layout, per
               `distributed.specs._int4_specs`), each shard contracts its
               local groups and the partial products `psum` over `model` —
               the same post-projection all-reduce the fp path pays.

    The activation row axis additionally shards over `data` when it
    divides.  Returns ``None`` when the active mesh has no model axis or
    the weight's sharded axis doesn't divide it (non-divisible shapes were
    placed replicated by the divisibility guard) — the caller then falls
    back to dequant+dot."""
    mesh = current_mesh()
    m = model_parallel_size(mesh)
    if mesh is None or m <= 1:
        return None
    from repro.kernels import quant_matmul as QM

    ng, _, N = w.packed.shape
    lead = x.shape[:-1]
    rows = 1
    for s in lead:
        rows *= s
    d = data_parallel_size(mesh)
    b = "data" if d > 1 and rows % d == 0 else None
    x2 = x.reshape(rows, x.shape[-1])
    scale = w.scale.astype(jnp.float32)
    zero = w.zero.astype(jnp.float32)

    if role == "col":
        if N % m:
            return None
        wspec = P(None, None, "model")

        def run(x2, packed, scale, zero):
            return QM.int4_matmul(x2, packed, scale, zero)

        out = _shard_map(run, mesh, (P(b, None), wspec, wspec, wspec),
                         P(b, "model"))(x2, w.packed, scale, zero)
    elif role == "row":
        if ng % m:
            return None
        wspec = P("model", None, None)

        def run(x2, packed, scale, zero):
            part = QM.int4_matmul(x2, packed, scale, zero)
            return jax.lax.psum(part, "model")

        out = _shard_map(run, mesh, (P(b, "model"), wspec, wspec, wspec),
                         P(b, None))(x2, w.packed, scale, zero)
    else:
        raise ValueError(f"unknown tp role {role!r}: expected col|row")
    return out.reshape(*lead, N)


def prefill_attention(q, k, v, q_start, kv_len, softcap: float = 0.0,
                      interpret: Optional[bool] = None):
    """Causal flash-prefill attention (serve-time prefill fast path).

    q ``[B, T, Hq, D]`` are the chunk's queries at stream positions
    ``q_start + [0, T)``; k/v ``[B, S, Hkv, D]`` hold the full key stream
    (prompt-so-far + chunk), of which the first ``kv_len`` positions are
    valid.  One-shot padded prefill is the ``q_start = 0, kv_len = L``
    special case; a mid-prompt chunk is the rectangular causal band
    ``q_start > 0``.  GQA folds the g query replicas into the row axis of
    the same ``[B·Hkv, g·T, D]`` layout the decode kernels use, so each KV
    tile is DMA'd once per kv-head."""
    if softcap != 0.0:
        raise NotImplementedError("softcap not fused in the Pallas kernel")
    B, T, Hq, D = q.shape
    Hkv = k.shape[2]

    def run(q, k, v, q_start, kv_len):
        Bl, Hl = q.shape[0], k.shape[2]
        gl = q.shape[2] // Hl
        qr = q.reshape(Bl, T, Hl, gl, D).transpose(0, 2, 3, 1, 4)
        qr = qr.reshape(Bl * Hl, gl * T, D)
        kr = k.transpose(0, 2, 1, 3).reshape(Bl * Hl, k.shape[1], D)
        vr = v.transpose(0, 2, 1, 3).reshape(Bl * Hl, v.shape[1], D)
        out = flash_prefill_attention(qr, kr, vr, q_start, kv_len, T,
                                      interpret=interpret)    # [BHl, gT, D]
        out = out.reshape(Bl, Hl, gl, T, D).transpose(0, 3, 1, 2, 4)
        return out.reshape(Bl, T, Hl * gl, D)

    args = (q, k, v, jnp.asarray(q_start, jnp.int32),
            jnp.asarray(kv_len, jnp.int32))
    mesh, b = _head_shard_ctx(Hkv, Hq, B)
    if mesh is None:
        return run(*args)
    spec = P(b, None, "model", None)
    return _shard_map(run, mesh, (spec, spec, spec, P(), P()), spec)(*args)
