"""jit'd wrappers tying the Pallas kernels to the cache/model layer.

`hier_attention` implements the same contract as
`models.common.attend_hier` (impl="pallas"): Pallas flash-decoding over the
quantized region + one jnp flash chunk for the FP buffer, merged by
log-sum-exp (paper App. E).

`paged_hier_attention` is the block-table analogue over a
`core.paged_kv_cache` pool: the Pallas kernel gathers each sequence's pool
blocks through a scalar-prefetched block table, and the per-slot FP buffers
form the extra flash chunk (per-slot stream positions — continuous
batching is ragged).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.hier_kv_cache import HierKVCache
from repro.core.paged_kv_cache import PagedKVPool, PageTable
from repro.kernels.quant_attention import (
    paged_quant_region_attention,
    quant_region_attention,
)


def _bh(x):
    """[B, NB, G, H, X] -> [B*H, NB, G, X]"""
    B, NB, G, H, X = x.shape
    return x.transpose(0, 3, 1, 2, 4).reshape(B * H, NB, G, X)


def _attention_with_lse(q, k, v, mask):
    """q [BH,gT,D]; k,v [BH,S,D]; mask [BH,gT,S] (True=attend).
    Returns normalized out + lse (−inf where no key valid)."""
    D = q.shape[-1]
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32))
    out = out / jnp.maximum(l, 1e-30)[..., None]
    lse = jnp.where(l > 0, m_safe + jnp.log(jnp.maximum(l, 1e-30)), -jnp.inf)
    return out, lse


def _combine(out_a, lse_a, out_b, lse_b, dtype):
    m = jnp.maximum(lse_a, lse_b)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    wa = jnp.exp(lse_a - m)[..., None]
    wb = jnp.exp(lse_b - m)[..., None]
    out = (out_a.astype(jnp.float32) * wa + out_b.astype(jnp.float32) * wb) \
        / jnp.maximum(wa + wb, 1e-30)
    return out.astype(dtype)


def hier_attention(q, cache: HierKVCache, stream_pos, mode: str,
                   softcap: float = 0.0, interpret: bool = True):
    """q [B, T, Hq, D] over a hierarchical cache (post-append).

    Draft mode streams 4 bits/KV element through the kernel, target mode 8 —
    the QuantSpec bandwidth win. Softcap is not fused (only needed by archs
    with softcap=0 here)."""
    if softcap != 0.0:
        raise NotImplementedError("softcap not fused in the Pallas kernel")
    B, T, Hq, D = q.shape
    H = cache.buf_k.shape[2]
    g = Hq // H
    G = cache.group

    # ---- quantized region via Pallas ---------------------------------------
    qr = q.reshape(B, T, H, g, D).transpose(0, 2, 3, 1, 4)  # [B,H,g,T,D]
    qr = qr.reshape(B * H, g * T, D)
    out_q, lse_q = quant_region_attention(
        qr,
        _bh(cache.k_upper), _bh(cache.k_lower),
        _bh(cache.k_scale), _bh(cache.k_zero),
        _bh(cache.v_upper), _bh(cache.v_lower),
        _bh(cache.v_scale), _bh(cache.v_zero),
        cache.blocks, mode, interpret=interpret)

    # ---- FP buffer chunk ----------------------------------------------------
    buf_k = cache.buf_k.transpose(0, 2, 1, 3).reshape(B * H, 2 * G, D)
    buf_v = cache.buf_v.transpose(0, 2, 1, 3).reshape(B * H, 2 * G, D)
    quant_len = cache.blocks * G
    t_idx = jnp.arange(g * T) % T
    q_pos = stream_pos + t_idx                                # [gT]
    j = jnp.arange(2 * G)
    mask = (j[None, :] < cache.buf_len) & \
           (quant_len + j[None, :] <= q_pos[:, None])         # [gT, 2G]
    mask = jnp.broadcast_to(mask[None], (B * H, g * T, 2 * G))
    out_b, lse_b = _attention_with_lse(qr, buf_k, buf_v, mask)

    out = _combine(out_q, lse_q, out_b, lse_b, q.dtype)       # [BH, gT, D]
    out = out.reshape(B, H, g, T, D).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, T, Hq, D)


def _pool_bh(x):
    """[P1, G|1, H, X] -> [P1*H, G|1, X] (row p*H + h)."""
    P1, G, H, X = x.shape
    return x.transpose(0, 2, 1, 3).reshape(P1 * H, G, X)


def paged_hier_attention(q, pool: PagedKVPool, table: PageTable, stream_pos,
                         mode: str, softcap: float = 0.0,
                         interpret: bool = True):
    """q [R, T, Hq, D] over a paged hierarchical cache (post-`apply_step`).

    `stream_pos` is per-slot [R] — the stream position of each slot's first
    query token (requests progress raggedly under continuous batching). The
    quantized pool is streamed through the block-table Pallas kernel; each
    slot's FP buffer is one extra flash chunk merged by log-sum-exp."""
    if softcap != 0.0:
        raise NotImplementedError("softcap not fused in the Pallas kernel")
    R, T, Hq, D = q.shape
    H = pool.buf_k.shape[2]
    g = Hq // H
    G = pool.group

    # ---- paged quantized region via Pallas ---------------------------------
    qr = q.reshape(R, T, H, g, D).transpose(0, 2, 3, 1, 4)   # [R,H,g,T,D]
    qr = qr.reshape(R * H, g * T, D)
    out_q, lse_q = paged_quant_region_attention(
        qr,
        _pool_bh(pool.k_upper), _pool_bh(pool.k_lower),
        _pool_bh(pool.k_scale), _pool_bh(pool.k_zero),
        _pool_bh(pool.v_upper), _pool_bh(pool.v_lower),
        _pool_bh(pool.v_scale), _pool_bh(pool.v_zero),
        table.block_table, table.blocks, H, mode, interpret=interpret)

    # ---- per-slot FP buffer chunk ------------------------------------------
    buf_k = pool.buf_k.transpose(0, 2, 1, 3).reshape(R * H, 2 * G, D)
    buf_v = pool.buf_v.transpose(0, 2, 1, 3).reshape(R * H, 2 * G, D)
    quant_len = table.blocks * G                              # [R]
    t_idx = jnp.arange(g * T) % T
    q_pos = jnp.asarray(stream_pos, jnp.int32)[:, None] + t_idx[None]  # [R,gT]
    j = jnp.arange(2 * G)
    mask = (j[None, None, :] < table.buf_len[:, None, None]) & \
           (quant_len[:, None, None] + j[None, None, :]
            <= q_pos[:, :, None])                             # [R, gT, 2G]
    mask = jnp.broadcast_to(mask[:, None], (R, H, g * T, 2 * G))
    mask = mask.reshape(R * H, g * T, 2 * G)
    out_b, lse_b = _attention_with_lse(qr, buf_k, buf_v, mask)

    out = _combine(out_q, lse_q, out_b, lse_b, q.dtype)       # [RH, gT, D]
    out = out.reshape(R, H, g, T, D).transpose(0, 3, 1, 2, 4)
    return out.reshape(R, T, Hq, D)
