"""Pure-jnp oracles for the Pallas kernels (shapes match the kernel API)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def unpack(p):
    return jnp.concatenate([(p >> 4).astype(jnp.int32),
                            (p & 0xF).astype(jnp.int32)], axis=-1)


def dequant_k(upper, lower, scale, zero, mode: str):
    """upper/lower [..., G, Dp]; scale/zero broadcastable [..., 1|G, D]."""
    qu = unpack(upper).astype(jnp.float32)
    if mode == "draft":
        return qu * scale + zero
    ql = unpack(lower).astype(jnp.float32) - 8.0
    return (16.0 * qu + ql) * (scale / 16.0) + zero


def quant_region_attention_ref(q, k_upper, k_lower, k_scale, k_zero,
                               v_upper, v_lower, v_scale, v_zero,
                               blocks, mode: str):
    """Flash-decoding reference over the quantized region only.

    q        [BH, gT, D]
    k/v_*    [BH, NB, G, Dp]; k_scale/zero [BH, NB, 1, D];
             v_scale/zero [BH, NB, G, 1]
    blocks   i32 — number of valid blocks
    Returns (out [BH, gT, D] normalized, lse [BH, gT]); empty region → lse=-inf.
    """
    BH, NB, G, Dp = k_upper.shape
    D = Dp * 2
    k = dequant_k(k_upper, k_lower, k_scale, k_zero, mode)   # [BH, NB, G, D]
    v = dequant_k(v_upper, v_lower, v_scale, v_zero, mode)
    k = k.reshape(BH, NB * G, D)
    v = v.reshape(BH, NB * G, D)
    valid = (jnp.arange(NB * G) // G) < blocks
    logits = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32), k)
    logits = logits / math.sqrt(D)
    logits = jnp.where(valid[None, None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(logits - m_safe[..., None])
    p = jnp.where(valid[None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bts,bsd->btd", p, v) / jnp.maximum(l, 1e-30)[..., None]
    lse = jnp.where(l > 0, m_safe + jnp.log(jnp.maximum(l, 1e-30)), -jnp.inf)
    return out.astype(q.dtype), lse


def paged_quant_region_attention_ref(q, k_upper, k_lower, k_scale, k_zero,
                                     v_upper, v_lower, v_scale, v_zero,
                                     block_table, blocks, nh: int, mode: str):
    """Oracle for the paged kernel: materialize the gather, then run the
    contiguous reference with per-sequence valid-block masks.

    q [R*H, gT, D]; pool planes [(P+1)*H, G, Dp] (row p*H + h);
    block_table [R, NBmax]; blocks [R].
    """
    RH, gT, D = q.shape
    R, NBmax = block_table.shape
    G = k_upper.shape[1]

    # gather pool rows into [RH, NBmax, ...]
    h = jnp.arange(RH) % nh                            # head of each q row
    rows = block_table[jnp.arange(RH) // nh] * nh + h[:, None]  # [RH, NBmax]
    gk = lambda a: a[rows]
    k = dequant_k(gk(k_upper), gk(k_lower), gk(k_scale), gk(k_zero), mode)
    v = dequant_k(gk(v_upper), gk(v_lower), gk(v_scale), gk(v_zero), mode)
    k = k.reshape(RH, NBmax * G, D)
    v = v.reshape(RH, NBmax * G, D)

    nblk = blocks[jnp.arange(RH) // nh]                # [RH]
    valid = (jnp.arange(NBmax * G)[None, :] // G) < nblk[:, None]
    logits = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32), k)
    logits = logits / math.sqrt(D)
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(logits - m_safe[..., None])
    p = jnp.where(valid[:, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bts,bsd->btd", p, v) / jnp.maximum(l, 1e-30)[..., None]
    lse = jnp.where(l > 0, m_safe + jnp.log(jnp.maximum(l, 1e-30)), -jnp.inf)
    return out.astype(q.dtype), lse


def quantize_kv_block_ref(k, v):
    """Hierarchically quantize one block. k,v [BH, G, D].
    Keys per-channel (reduce over G), values per-token (reduce over D).
    Returns dict of (upper, lower packed [BH, G, D//2], scale, zero)."""
    from repro.core.quantization import quantize_k_block, quantize_v_block
    # adapt: core fns expect [..., G, H, D]; insert H=1
    kq = quantize_k_block(k[:, :, None, :])
    vq = quantize_v_block(v[:, :, None, :])
    sq = lambda t: t.squeeze(2)
    return {
        "k_upper": sq(kq.upper), "k_lower": sq(kq.lower),
        "k_scale": kq.scale.squeeze(2), "k_zero": kq.zero.squeeze(2),
        "v_upper": sq(vq.upper), "v_lower": sq(vq.lower),
        "v_scale": sq(vq.scale), "v_zero": sq(vq.zero),
    }
