"""Pure-jnp oracles for the Pallas kernels (shapes match the kernel API)."""

from __future__ import annotations

import math

import jax.numpy as jnp


def unpack(p):
    return jnp.concatenate([(p >> 4).astype(jnp.int32),
                            (p & 0xF).astype(jnp.int32)], axis=-1)


def dequant_k(upper, lower, scale, zero, mode: str):
    """upper/lower [..., G, Dp]; scale/zero broadcastable [..., 1|G, D]."""
    qu = unpack(upper).astype(jnp.float32)
    if mode == "draft":
        return qu * scale + zero
    ql = unpack(lower).astype(jnp.float32) - 8.0
    return (16.0 * qu + ql) * (scale / 16.0) + zero


def quant_region_attention_ref(q, k_upper, k_lower, k_scale, k_zero,
                               v_upper, v_lower, v_scale, v_zero,
                               blocks, mode: str):
    """Flash-decoding reference over the quantized region only.

    q        [BH, gT, D]
    k/v_*    [BH, NB, G, Dp]; k_scale/zero [BH, NB, 1, D];
             v_scale/zero [BH, NB, G, 1]
    blocks   i32 — number of valid blocks
    Returns (out [BH, gT, D] normalized, lse [BH, gT]); empty region → lse=-inf.
    """
    BH, NB, G, Dp = k_upper.shape
    D = Dp * 2
    k = dequant_k(k_upper, k_lower, k_scale, k_zero, mode)   # [BH, NB, G, D]
    v = dequant_k(v_upper, v_lower, v_scale, v_zero, mode)
    k = k.reshape(BH, NB * G, D)
    v = v.reshape(BH, NB * G, D)
    valid = (jnp.arange(NB * G) // G) < blocks
    logits = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32), k)
    logits = logits / math.sqrt(D)
    logits = jnp.where(valid[None, None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(logits - m_safe[..., None])
    p = jnp.where(valid[None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bts,bsd->btd", p, v) / jnp.maximum(l, 1e-30)[..., None]
    lse = jnp.where(l > 0, m_safe + jnp.log(jnp.maximum(l, 1e-30)), -jnp.inf)
    return out.astype(q.dtype), lse


def paged_quant_region_attention_ref(q, k_upper, k_lower, k_scale, k_zero,
                                     v_upper, v_lower, v_scale, v_zero,
                                     block_table, blocks, nh: int, mode: str):
    """Oracle for the paged kernel: materialize the gather, then run the
    contiguous reference with per-sequence valid-block masks.

    q [R*H, gT, D]; pool planes [(P+1)*H, G, Dp] (row p*H + h);
    block_table [R, NBmax]; blocks [R].
    """
    RH, gT, D = q.shape
    R, NBmax = block_table.shape
    G = k_upper.shape[1]

    # gather pool rows into [RH, NBmax, ...]
    h = jnp.arange(RH) % nh                            # head of each q row
    rows = block_table[jnp.arange(RH) // nh] * nh + h[:, None]  # [RH, NBmax]
    gk = lambda a: a[rows]
    k = dequant_k(gk(k_upper), gk(k_lower), gk(k_scale), gk(k_zero), mode)
    v = dequant_k(gk(v_upper), gk(v_lower), gk(v_scale), gk(v_zero), mode)
    k = k.reshape(RH, NBmax * G, D)
    v = v.reshape(RH, NBmax * G, D)

    nblk = blocks[jnp.arange(RH) // nh]                # [RH]
    valid = (jnp.arange(NBmax * G)[None, :] // G) < nblk[:, None]
    logits = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32), k)
    logits = logits / math.sqrt(D)
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(logits - m_safe[..., None])
    p = jnp.where(valid[:, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bts,bsd->btd", p, v) / jnp.maximum(l, 1e-30)[..., None]
    lse = jnp.where(l > 0, m_safe + jnp.log(jnp.maximum(l, 1e-30)), -jnp.inf)
    return out.astype(q.dtype), lse


def _attention_with_lse(q, k, v, mask):
    """q [BH,gT,D]; k,v [BH,S,D]; mask [BH,gT,S] (True=attend).
    Returns normalized out + lse (−inf where no key valid)."""
    D = q.shape[-1]
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32))
    out = out / jnp.maximum(l, 1e-30)[..., None]
    lse = jnp.where(l > 0, m_safe + jnp.log(jnp.maximum(l, 1e-30)), -jnp.inf)
    return out, lse


def _combine(out_a, lse_a, out_b, lse_b, dtype):
    m = jnp.maximum(lse_a, lse_b)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    wa = jnp.exp(lse_a - m)[..., None]
    wb = jnp.exp(lse_b - m)[..., None]
    out = (out_a.astype(jnp.float32) * wa + out_b.astype(jnp.float32) * wb) \
        / jnp.maximum(wa + wb, 1e-30)
    return out.astype(dtype)


def hier_attention_twopass_ref(q, k_upper, k_lower, k_scale, k_zero,
                               v_upper, v_lower, v_scale, v_zero,
                               buf_k, buf_v, blocks, buf_len, stream_pos,
                               T: int, mode: str):
    """The *old two-pass path* at the kernel API level: quantized-region
    flash (ref) + an FP-buffer chunk with a materialized ``[BH, gT, 2G]``
    mask, merged by log-sum-exp.  Oracle for the single-pass
    ``hier_flash_attention`` (same operand layouts)."""
    BH, gT, D = q.shape
    G = k_upper.shape[2]
    out_q, lse_q = quant_region_attention_ref(
        q, k_upper, k_lower, k_scale, k_zero,
        v_upper, v_lower, v_scale, v_zero, blocks, mode)

    quant_len = blocks * G
    t_idx = jnp.arange(gT) % T
    q_pos = stream_pos + t_idx                                # [gT]
    j = jnp.arange(2 * G)
    mask = (j[None, :] < buf_len) & \
           (quant_len + j[None, :] <= q_pos[:, None])         # [gT, 2G]
    mask = jnp.broadcast_to(mask[None], (BH, gT, 2 * G))
    out_b, lse_b = _attention_with_lse(q, buf_k, buf_v, mask)
    return _combine(out_q, lse_q, out_b, lse_b, q.dtype)


def paged_hier_attention_twopass_ref(q, k_upper, k_lower, k_scale, k_zero,
                                     v_upper, v_lower, v_scale, v_zero,
                                     buf_k, buf_v, block_table, blocks,
                                     buf_len, stream_pos, nh: int, T: int,
                                     mode: str):
    """Paged analogue of :func:`hier_attention_twopass_ref` — oracle for
    ``paged_hier_flash_attention`` (per-slot ragged positions)."""
    RH, gT, D = q.shape
    G = k_upper.shape[1]
    out_q, lse_q = paged_quant_region_attention_ref(
        q, k_upper, k_lower, k_scale, k_zero,
        v_upper, v_lower, v_scale, v_zero, block_table, blocks, nh, mode)

    quant_len = blocks * G                                    # [R]
    t_idx = jnp.arange(gT) % T
    q_pos = jnp.asarray(stream_pos, jnp.int32)[:, None] + t_idx[None]  # [R,gT]
    j = jnp.arange(2 * G)
    mask = (j[None, None, :] < buf_len[:, None, None]) & \
           (quant_len[:, None, None] + j[None, None, :]
            <= q_pos[:, :, None])                             # [R, gT, 2G]
    R = block_table.shape[0]
    mask = jnp.broadcast_to(mask[:, None], (R, nh, gT, 2 * G))
    mask = mask.reshape(RH, gT, 2 * G)
    out_b, lse_b = _attention_with_lse(q, buf_k, buf_v, mask)
    return _combine(out_q, lse_q, out_b, lse_b, q.dtype)


def prefill_attention_ref(q, k, v, q_start, kv_len, T: int):
    """Oracle for ``flash_prefill_attention`` (same operand layouts).

    q ``[BH, gT, D]`` — g GQA replicas × T positions (row r at stream
    position ``q_start + r % T``); k/v ``[BH, S, D]`` with the first
    ``kv_len`` keys valid.  Returns the normalized output ``[BH, gT, D]``.
    """
    BH, gT, D = q.shape
    S = k.shape[1]
    q_pos = q_start + jnp.arange(gT) % T                       # [gT]
    k_pos = jnp.arange(S)
    mask = (k_pos[None, :] <= q_pos[:, None]) & \
        (k_pos[None, :] < kv_len)                              # [gT, S]
    mask = jnp.broadcast_to(mask[None], (BH, gT, S))
    out, _ = _attention_with_lse(q, k, v, mask)
    return out.astype(q.dtype)


def quantize_kv_block_ref(k, v):
    """Hierarchically quantize one block. k,v [BH, G, D].
    Keys per-channel (reduce over G), values per-token (reduce over D).
    Returns dict of (upper, lower packed [BH, G, D//2], scale, zero)."""
    from repro.core.quantization import quantize_k_block, quantize_v_block
    # adapt: core fns expect [..., G, H, D]; insert H=1
    kq = quantize_k_block(k[:, :, None, :])
    vq = quantize_v_block(v[:, :, None, :])
    sq = lambda t: t.squeeze(2)
    return {
        "k_upper": sq(kq.upper), "k_lower": sq(kq.lower),
        "k_scale": kq.scale.squeeze(2), "k_zero": kq.zero.squeeze(2),
        "v_upper": sq(vq.upper), "v_lower": sq(vq.lower),
        "v_scale": sq(vq.scale), "v_zero": sq(vq.zero),
    }
