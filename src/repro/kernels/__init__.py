"""Pallas TPU kernels for the hierarchical quantized KV cache (contiguous
and block-table paged flash decoding), the causal flash-prefill kernel
(prefill_attention.py), their pure-jnp oracles (ref.py), and the jit
wrappers tying kernels to the cache/model layer (ops.py)."""

from __future__ import annotations

import os


def resolve_impl(env_var: str, tpu_impl: str, fallback: str) -> str:
    """Shared env-var/backend dispatch for every kernel fast path.

    ``env_var`` ∈ {auto, ``tpu_impl``, ``fallback``}: 'auto' picks the
    kernel implementation only on a real TPU backend — in interpret mode
    the kernels are parity tools, not fast paths."""
    impl = os.environ.get(env_var, "auto")
    if impl == "auto":
        import jax

        return tpu_impl if jax.default_backend() == "tpu" else fallback
    return impl


def interpret_default() -> bool:
    """Backend-aware default for every kernel's ``interpret`` flag.

    Pallas kernels compile to real TPU programs on a TPU backend and run in
    the (slow, but numerically faithful) interpreter everywhere else —
    previously each entry point hardcoded ``interpret=True`` and callers had
    to thread the right value through by hand.

    ``REPRO_PALLAS_INTERPRET`` overrides: ``1``/``true`` forces interpret
    mode (e.g. to exercise the interpreter on TPU in tests), ``0``/``false``
    forces compiled mode. Unset/``auto`` → interpret only off-TPU.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET", "auto").lower()
    if env in ("1", "true", "yes", "interpret"):
        return True
    if env in ("0", "false", "no", "compile"):
        return False
    import jax

    return jax.default_backend() != "tpu"
