"""Pallas TPU kernels for the hierarchical quantized KV cache (contiguous
and block-table paged flash decoding), their pure-jnp oracles (ref.py), and
the jit wrappers tying kernels to the cache/model layer (ops.py)."""
