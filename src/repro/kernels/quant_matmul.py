"""Pallas TPU kernel: fused INT4 dequant×matmul for the draft linear path.

QuantSpec §3.1: short-context decode is *weight*-bound — every decode step
streams the full weight matrix through HBM for a handful of activation
rows.  The jnp reference path (``Int4Weight.dequant() @ x``) materializes
the fp32 weight before the dot, moving ``4 + 0.5`` bytes per element
(packed read + fp32 round-trip when XLA fails to fuse).  This kernel keeps
the weight packed all the way into VMEM and dequantizes in-register per
``[group, TN]`` tile, so HBM traffic is the packed plane + per-group
scale/zero only — the INT4 bandwidth win applied to the matmul half of
decode.

Layout (matches ``core.weight_quant.quantize_weight``):

    packed  uint8 [ng, group//2, N]   row r of a packed group holds logical
                                      rows (2r, 2r+1): hi nibble = even row
    scale   f32   [ng, 1, N]
    zero    f32   [ng, 1, N]

Grid = (N // TN, ng): the contraction (quant-group) axis is innermost so a
fp32 accumulator tile ``[M, TN]`` lives in VMEM scratch across grid steps;
each step DMAs one ``[group//2, TN]`` packed tile + its scale/zero row and
one ``[M, group]`` activation tile, unpacks the two nibble planes, applies
``q * scale + zero`` and feeds the MXU.  Output is written once, at the
last contraction step.

Validated in interpret mode against ``Int4Weight.dequant() @ x``
(tests/test_quant_matmul.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import interpret_default

# Decode activations are a few rows; above this the matmul is compute-bound
# and the dequant+dot path (MXU-friendly fp tiles, XLA fusion) wins.
MAX_FUSED_ROWS = 1024


def _kernel(x_ref, p_ref, s_ref, z_ref, o_ref, acc_scr, *, ng: int):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    p = p_ref[0]                                   # [group//2, TN] uint8
    hi = (p >> 4).astype(jnp.float32)
    lo = (p & 0xF).astype(jnp.float32)
    gh, tn = p.shape
    # packed row r holds logical rows (2r, 2r+1) → interleave back
    w = jnp.stack([hi, lo], axis=1).reshape(2 * gh, tn)
    w = w * s_ref[0] + z_ref[0]                    # [group, TN]

    x = x_ref[...].astype(jnp.float32)             # [M, group]
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kk == ng - 1)
    def _finalize():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def int4_matmul(x, packed, scale, zero, *, interpret: Optional[bool] = None):
    """``x [M, K] @ dequant(packed, scale, zero) [K, N] -> [M, N]``.

    ``K = ng * group`` with ``group = 2 * packed.shape[1]``. The weight
    never materializes in HBM: dequantization happens in-register after the
    VMEM copy of each packed tile.
    """
    if interpret is None:
        interpret = interpret_default()
    M, K = x.shape
    ng, gh, N = packed.shape
    group = 2 * gh
    assert K == ng * group, (x.shape, packed.shape)

    TN = 128 if N % 128 == 0 else N
    grid = (N // TN, ng)

    out = pl.pallas_call(
        functools.partial(_kernel, ng=ng),
        grid=grid,
        in_specs=[
            pl.BlockSpec((M, group), lambda n, kk: (0, kk)),
            pl.BlockSpec((1, gh, TN), lambda n, kk: (kk, 0, n)),
            pl.BlockSpec((1, 1, TN), lambda n, kk: (kk, 0, n)),
            pl.BlockSpec((1, 1, TN), lambda n, kk: (kk, 0, n)),
        ],
        out_specs=pl.BlockSpec((M, TN), lambda n, kk: (0, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((M, TN), jnp.float32)],
        interpret=interpret,
    )(x, packed, scale.astype(jnp.float32), zero.astype(jnp.float32))
    return out


def fused_matmul(x, w, *, interpret: Optional[bool] = None):
    """``x [..., K]`` times an :class:`~repro.core.weight_quant.Int4Weight`
    (duck-typed: needs ``.packed/.scale/.zero``; 2-D logical weights only).
    Leading activation dims are flattened into the row axis."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    out = int4_matmul(x2, w.packed, w.scale, w.zero, interpret=interpret)
    return out.reshape(*lead, out.shape[-1])


def supports(x, w) -> bool:
    """Whether the fused kernel handles this (activation, weight) pair:
    2-D logical weight, modest row count (decode shapes)."""
    packed = getattr(w, "packed", None)
    if packed is None or packed.ndim != 3:
        return False
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    return rows <= MAX_FUSED_ROWS
