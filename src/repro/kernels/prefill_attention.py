"""Pallas TPU kernel: causal flash-attention for serve-time prefill.

Long-context serving (the paper's 32k–512k regime) is admission-bound once
decode is bandwidth-optimal: the jnp prefill path materializes per-chunk
``[B, Hkv, g, Tc, S]`` logits, so a 128k prompt moves O(S²) float32 through
HBM besides the O(S²) FLOPs it owes.  This kernel is the classic
query-block × key-block flash schedule instead: grid ``(B·Hkv, NQ, NK)``
with the online-softmax state ``(m, l, acc)`` carried in VMEM scratch
across the (innermost, sequential) key-block axis — logits never leave
registers.

GQA uses the same ``[B·Hkv, g·T, D]`` layout as the decode kernels
(kernels/quant_attention.py): the g query replicas of one KV head are
stacked along the row axis, so each key/value tile is DMA'd **once per
kv-head**, not once per query head; a row's stream position is
``q_start + row % T``.

The same kernel serves both prefill shapes:

  * one-shot padded prefill (static engine): ``q_start = 0`` and
    ``kv_len = L`` masks the bucket-padding tail, so one compiled program
    covers every prompt length in a bucket;
  * a mid-prompt chunk (chunked paged prefill): queries at stream
    positions ``q_start + [0, T)`` over the full key stream so far — a
    rectangular causal band.  Key blocks entirely above the band's causal
    frontier or past ``kv_len`` are skipped via ``pl.when``.

Both scalars are prefetched (``PrefetchScalarGridSpec``), so chunk
position/raggedness never triggers a recompile — compile cost is
O(#chunk-buckets), not O(#prompt lengths).

The pure-jnp oracle is ``kernels/ref.py::prefill_attention_ref``; the
model-level jnp path (`models.common.serve_prefill_attention`) remains the
train-mode implementation and the parity reference.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import interpret_default
from repro.kernels.quant_attention import _flash_init, _flash_out, _fold


def _block_size(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is ≤ ``target`` (TPU-aligned shapes
    divide evenly; ragged test shapes degrade gracefully)."""
    b = min(n, target)
    while n % b:
        b -= 1
    return b


def _prefill_kernel(meta_ref, q_ref, k_ref, v_ref, out_ref,
                    m_scr, l_scr, acc_scr, *, T: int, QB: int, KB: int,
                    NK: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    q0 = meta_ref[0]
    kv_len = meta_ref[1]

    @pl.when(kb == 0)
    def _init():
        _flash_init(m_scr, l_scr, acc_scr)

    # rows of q-block qb are one contiguous position run (QB divides T):
    # row r holds stream position q0 + r % T
    blk_hi = q0 + (qb * QB) % T + QB - 1          # newest query in block

    @pl.when((kb * KB <= blk_hi) & (kb * KB < kv_len))
    def _process():
        q = q_ref[0].astype(jnp.float32)           # [QB, D]
        k = k_ref[0].astype(jnp.float32)           # [KB, D]
        v = v_ref[0].astype(jnp.float32)
        D = q.shape[-1]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / math.sqrt(D))               # [QB, KB]
        row = jax.lax.broadcasted_iota(jnp.int32, (QB, KB), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (QB, KB), 1)
        q_pos = q0 + (qb * QB + row) % T
        k_pos = kb * KB + col
        mask = (k_pos <= q_pos) & (k_pos < kv_len)
        _fold(s, v, mask, m_scr, l_scr, acc_scr)

    @pl.when(kb == NK - 1)
    def _finalize():
        _flash_out(out_ref, m_scr, l_scr, acc_scr)


def flash_prefill_attention(q, k, v, q_start, kv_len, T: int, *,
                            q_block: int = 128, k_block: int = 128,
                            interpret: Optional[bool] = None):
    """Causal flash prefill: q ``[BH, gT, D]`` (g GQA replicas × T query
    positions, T inner), k/v ``[BH, S, D]``.

    ``q_start`` (stream position of the chunk's first query) and ``kv_len``
    (valid key prefix, ≤ S) are traced i32 scalars.  Query row ``r``
    attends keys ``[0, min(q_start + r % T, kv_len - 1)]``.  Returns out
    ``[BH, gT, D]``, softmax-normalized — padded queries (callers mask by
    position) produce finite garbage rows.
    """
    if interpret is None:
        interpret = interpret_default()
    BH, gT, D = q.shape
    S = k.shape[1]
    assert gT % T == 0, (q.shape, T)
    QB = _block_size(T, q_block)                  # QB | T ⇒ QB | gT
    KB = _block_size(S, k_block)
    NQ = gT // QB
    NK = S // KB

    meta = jnp.stack([jnp.asarray(q_start, jnp.int32).reshape(()),
                      jnp.asarray(kv_len, jnp.int32).reshape(())])

    out = pl.pallas_call(
        functools.partial(_prefill_kernel, T=T, QB=QB, KB=KB, NK=NK),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(BH, NQ, NK),
            in_specs=[
                pl.BlockSpec((1, QB, D), lambda i, qb, kb, m: (i, qb, 0)),
                pl.BlockSpec((1, KB, D), lambda i, qb, kb, m: (i, kb, 0)),
                pl.BlockSpec((1, KB, D), lambda i, qb, kb, m: (i, kb, 0)),
            ],
            out_specs=pl.BlockSpec((1, QB, D),
                                   lambda i, qb, kb, m: (i, qb, 0)),
            scratch_shapes=[pltpu.VMEM((QB, 1), jnp.float32),
                            pltpu.VMEM((QB, 1), jnp.float32),
                            pltpu.VMEM((QB, D), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((BH, gT, D), q.dtype),
        interpret=interpret,
    )(meta, q, k, v)
    return out
