"""PartitionSpec assignment for parameter and serving-state pytrees.

Parameters: name-based rules over the trailing two (matrix) axes.
  serve : output-feature dims of QKV/up projections → `model`; input dims of
          down/output projections → `model`; vocab → `model`; rest replicated
          (weights replicated across `data` so each data replica decodes
          independently).
  train : same `model` placement + the opposite matrix dim → `data` (FSDP),
          so params/grads/AdamW state shard over all 256|512 chips.

Expert tensors additionally shard their expert axis over `model`
(expert parallelism); the per-expert matrix dims then only use `data`.

Serving state: structural walk over the cache containers (type dispatch,
no name parsing): batch → `data`, kv-heads → `model`; in long-context mode
(batch=1) the cache *sequence* axis shards over `data` instead — chip-level
flash-decoding (DESIGN.md §2).

Paged serving state (continuous batching): the shared `PagedKVPool` planes
(packed INT4 upper/lower + scales/zeros) shard their kv-head axis over
`model` and replicate the pool-block axis (the pool is shared by every
slot); the per-slot FP buffers shard slots → `data`, heads → `model`.
`PageTable` bookkeeping and transient `PrefillScratch` stay replicated
except the scratch's kv-head axis (→ `model`, matching the K/V projections
that write it); the megastep's device-resident per-slot request state
(`SlotState`) is replicated like the table it rides next to.

Quantized draft params: `Int4Weight` leaves spec their packed/scale/zero
planes like the fp matrix they quantize — the in-dim role lands on the
group axis (`d_in//group`, axis -3) and the out-dim role on `d_out`
(axis -1), so e.g. `wo`/`w_down` stay contraction-sharded and the
post-projection all-reduce is the only collective, exactly as in fp.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import hier_kv_cache as HC
from repro.core import paged_kv_cache as PC
from repro.core.weight_quant import Int4Weight
from repro.models import mamba as M
from repro.models import rwkv6 as R
from repro.models.stack import AttnState, CrossKV, SnapKVCache

# name -> (in_dim_role, out_dim_role); roles: 'model' | 'fsdp' | None
_MATRIX_ROLES = {
    "wq": ("fsdp", "model"), "wk": ("fsdp", "model"), "wv": ("fsdp", "model"),
    "wo": ("model", "fsdp"),
    "w_gate": ("fsdp", "model"), "w_up": ("fsdp", "model"),
    "w_down": ("model", "fsdp"),
    "in_proj": ("fsdp", "model"), "out_proj": ("model", "fsdp"),
    "x_proj": ("model", None), "dt_w": (None, "model"),
    "wr": ("fsdp", "model"), "wg": ("fsdp", "model"),
    "wr_cm": ("fsdp", "model"), "wk_cm": ("fsdp", "model"),
    "wv_cm": ("model", "fsdp"),
    "w_lora_a": (None, None), "w_lora_b": (None, None),
    "embed": ("model", "fsdp"),       # [V, d]
    "lm_head": ("fsdp", "model"),     # [d, V]
    "router": (None, None),
    "conv_w": (None, "model"),
}

_REPLICATED_HINTS = ("norm", "bias", "scale", "zero", "mu_", "w0",
                     "a_log", "d_skip", "dt_bias", "ln_")
_REPLICATED_EXACT = ("u",)  # RWKV per-head bonus


def _role_axis(role, mode: str, mesh: Mesh):
    if role == "model":
        return "model" if "model" in mesh.axis_names else None
    if role == "fsdp" and mode == "train":
        return "data" if "data" in mesh.axis_names else None
    return None


def _leaf_name(path) -> str:
    for entry in reversed(path):
        s = str(getattr(entry, "key", getattr(entry, "name", entry)))
        if not s.isdigit():
            return s.strip("'\"[]")
    return ""


def _int4_specs(leaf: Int4Weight, path, mesh: Mesh, mode: str) -> Int4Weight:
    """Spec an :class:`Int4Weight` like the fp matrix it quantizes.

    Packed layout is ``[*lead, d_in//group, group//2, d_out]`` (scales/zeros
    ``[*lead, d_in//group, 1, d_out]``): the matrix in-dim role goes on the
    group axis (-3) and the out-dim role on ``d_out`` (-1) for every plane,
    so a sharded draft tree never replicates the packed planes and the
    contraction stays aligned with the fp activations."""
    pathstr = jax.tree_util.keystr(path)
    name = _leaf_name(path)
    lead = leaf.packed.ndim - 3
    in_ax = out_ax = None
    Lp = [None] * lead
    if "experts" in pathstr and name in ("w_gate", "w_up", "w_down"):
        if lead >= 1:
            Lp[-1] = _role_axis("model", mode, mesh)
        in_ax = _role_axis("fsdp", mode, mesh)
    else:
        roles = _MATRIX_ROLES.get(name)
        if roles is not None:
            in_ax = _role_axis(roles[0], mode, mesh)
            out_ax = _role_axis(roles[1], mode, mesh)
    plane = lambda x: _fit(mesh, x.shape, (*Lp, in_ax, None, out_ax))
    return Int4Weight(plane(leaf.packed), plane(leaf.scale),
                      plane(leaf.zero), leaf.group)


def param_specs(params, mesh: Mesh, mode: str = "serve"):
    """Pytree of NamedSharding mirroring `params` (including quantized
    `Int4Weight` draft trees, whose packed/scale/zero planes are spec'd
    like the fp matrix they quantize)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, Int4Weight))
    out = []
    for path, leaf in flat:
        if isinstance(leaf, Int4Weight):
            out.append(_int4_specs(leaf, path, mesh, mode))
            continue
        pathstr = jax.tree_util.keystr(path)
        name = _leaf_name(path)
        ndim = np.ndim(leaf)
        spec = P()
        if ndim >= 2:
            is_expert = "experts" in pathstr and name in (
                "w_gate", "w_up", "w_down")
            roles = _MATRIX_ROLES.get(name)
            if (any(h in name.lower() for h in _REPLICATED_HINTS)
                    or name.lower() in _REPLICATED_EXACT):
                roles = None
            if is_expert:
                # [..., E, d_in, d_out]: E -> model, d_in -> fsdp
                parts = [None] * ndim
                parts[-3] = _role_axis("model", mode, mesh)
                parts[-2] = _role_axis("fsdp", mode, mesh)
                spec = P(*parts)
            elif roles is not None and ndim >= 2:
                parts = [None] * ndim
                parts[-2] = _role_axis(roles[0], mode, mesh)
                parts[-1] = _role_axis(roles[1], mode, mesh)
                spec = P(*parts)
        # divisibility guard
        shape = np.shape(leaf)
        parts = list(tuple(spec) + (None,) * (ndim - len(tuple(spec))))
        for i, part in enumerate(parts):
            if part is None:
                continue
            if shape[i] % mesh.shape[part] != 0:
                parts[i] = None
        out.append(NamedSharding(mesh, P(*parts)))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# serving state
# ---------------------------------------------------------------------------

def _fit(mesh: Mesh, shape, parts):
    """Drop spec entries whose mesh extent doesn't divide the dim size."""
    out = []
    for i, part in enumerate(parts[: len(shape)]):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        extent = 1
        for a in axes:
            extent *= mesh.shape[a]
        ok = extent > 0 and shape[i] % extent == 0
        out.append((axes if len(axes) > 1 else axes[0]) if ok and axes else None)
    while out and out[-1] is None:
        out.pop()
    return NamedSharding(mesh, P(*out))


def _cache_spec(obj, mesh: Mesh, long_ctx: bool, lead: int):
    """Spec tree for one cache object; `lead` = number of stacked leading
    axes (n_repeats) to pad with None.

    Head axis shards over `model` when divisible; otherwise the sequence
    (block) axis takes `model` — chip-level flash-decoding over the cache.
    long_ctx (batch=1): batch unsharded, sequence over `data` (+`model` if
    heads don't fit)."""
    Lp = (None,) * lead
    model_n = mesh.shape.get("model", 1)

    def kv_like(shape_head_axis, leaf):
        import os
        H = leaf.shape[shape_head_axis]
        heads_ok = H % model_n == 0
        h = "model" if heads_ok else None
        if long_ctx:
            b = None
            # REPRO_LONG_SEQ_DATA_ONLY=1: don't put `model` on the cache
            # sequence even when heads don't divide (§Perf pair-C iteration:
            # trades idle model shards for no cross-`model` gather)
            data_only = os.environ.get("REPRO_LONG_SEQ_DATA_ONLY") == "1"
            seq = ("data",) if (heads_ok or data_only) else ("data", "model")
        else:
            b = "data"
            seq = None if heads_ok else "model"
        return b, seq, h

    if isinstance(obj, HC.HierKVCache):
        b, seq, h = kv_like(-2, obj.k_upper)
        plane = lambda leaf: _fit(mesh, leaf.shape, (*Lp, b, seq, None, h, None))
        return HC.HierKVCache(
            k_upper=plane(obj.k_upper), k_lower=plane(obj.k_lower),
            k_scale=plane(obj.k_scale), k_zero=plane(obj.k_zero),
            v_upper=plane(obj.v_upper), v_lower=plane(obj.v_lower),
            v_scale=plane(obj.v_scale), v_zero=plane(obj.v_zero),
            blocks=_fit(mesh, obj.blocks.shape, Lp),
            buf_k=_fit(mesh, obj.buf_k.shape, (*Lp, b, None, h, None)),
            buf_v=_fit(mesh, obj.buf_v.shape, (*Lp, b, None, h, None)),
            buf_len=_fit(mesh, obj.buf_len.shape, Lp),
        )
    if isinstance(obj, HC.FullKVCache):
        b, seq, h = kv_like(-2, obj.k)
        kv = lambda leaf: _fit(mesh, leaf.shape, (*Lp, b, seq, h, None))
        return HC.FullKVCache(k=kv(obj.k), v=kv(obj.v),
                              length=_fit(mesh, obj.length.shape, Lp))
    if isinstance(obj, HC.WindowKVCache):
        b, seq, h = kv_like(-2, obj.ring_k)
        kv = lambda leaf: _fit(mesh, leaf.shape, (*Lp, b, seq, h, None))
        sink = lambda leaf: _fit(mesh, leaf.shape, (*Lp, b, None, h, None))
        return HC.WindowKVCache(
            sink_k=sink(obj.sink_k), sink_v=sink(obj.sink_v),
            ring_k=kv(obj.ring_k), ring_v=kv(obj.ring_v),
            pos=_fit(mesh, obj.pos.shape, Lp))
    if isinstance(obj, SnapKVCache):
        b, seq, h = kv_like(-2, obj.sel_k)
        kv = lambda leaf: _fit(mesh, leaf.shape, (*Lp, b, None, h, None))
        return SnapKVCache(
            sel_k=kv(obj.sel_k), sel_v=kv(obj.sel_v),
            sel_pos=_fit(mesh, obj.sel_pos.shape, (*Lp, b)),
            recent=_cache_spec(obj.recent, mesh, long_ctx, lead))
    if isinstance(obj, PC.PagedKVPool):
        # Shared block pool: every slot's quantized groups live here, so the
        # pool-block axis is replicated (and shared across `data` replicas);
        # the kv-head axis shards over `model` — packed INT4 planes, scales
        # and zeros alike (all keep heads at axis 2 past the lead). Per-slot
        # FP buffers shard slots → `data`, heads → `model`.
        plane = lambda leaf: _fit(mesh, leaf.shape,
                                  (*Lp, None, None, "model", None))
        buf = lambda leaf: _fit(mesh, leaf.shape,
                                (*Lp, "data", None, "model", None))
        return PC.PagedKVPool(
            k_upper=plane(obj.k_upper), k_lower=plane(obj.k_lower),
            k_scale=plane(obj.k_scale), k_zero=plane(obj.k_zero),
            v_upper=plane(obj.v_upper), v_lower=plane(obj.v_lower),
            v_scale=plane(obj.v_scale), v_zero=plane(obj.v_zero),
            buf_k=buf(obj.buf_k), buf_v=buf(obj.buf_v))
    if isinstance(obj, PC.PrefillScratch):
        # transient batch-1 fp prompt history: kv-heads → `model` (matching
        # the K/V projections that write it), everything else replicated
        kv = lambda leaf: _fit(mesh, leaf.shape,
                               (*Lp, None, None, "model", None))
        return PC.PrefillScratch(k=kv(obj.k), v=kv(obj.v))
    if isinstance(obj, CrossKV):
        b, _, h = kv_like(-2, obj.k)
        kv = lambda leaf: _fit(mesh, leaf.shape, (*Lp, b, None, h, None))
        return CrossKV(k=kv(obj.k), v=kv(obj.v))
    if isinstance(obj, AttnState):
        return AttnState(
            primary=_cache_spec(obj.primary, mesh, long_ctx, lead),
            draft=(None if obj.draft is None
                   else _cache_spec(obj.draft, mesh, long_ctx, lead)))
    if isinstance(obj, M.MambaCache):
        b = None if long_ctx else "data"
        return M.MambaCache(
            conv=_fit(mesh, obj.conv.shape, (*Lp, b, None, "model")),
            h=_fit(mesh, obj.h.shape, (*Lp, b, "model", None)))
    if isinstance(obj, R.RWKVTMState):
        b = None if long_ctx else "data"
        return R.RWKVTMState(
            x_prev=_fit(mesh, obj.x_prev.shape, (*Lp, b, None)),
            S=_fit(mesh, obj.S.shape, (*Lp, b, "model", None, None)))
    if isinstance(obj, R.RWKVCMState):
        b = None if long_ctx else "data"
        return R.RWKVCMState(
            x_prev=_fit(mesh, obj.x_prev.shape, (*Lp, b, None)))
    if obj is None:
        return None
    raise TypeError(type(obj))


def state_specs(state, mesh: Mesh, long_ctx: bool = False):
    """Spec tree mirroring a serve state (dict head/blocks/tail of
    (mixer, mlp) pairs)."""
    def entry(pair, lead):
        mixer, mlp = pair
        return (_cache_spec(mixer, mesh, long_ctx, lead),
                _cache_spec(mlp, mesh, long_ctx, lead))

    return {
        "head": [entry(p, 0) for p in state["head"]],
        "tail": [entry(p, 0) for p in state["tail"]],
        "blocks": (tuple(entry(p, 1) for p in state["blocks"])
                   if state["blocks"] is not None else None),
    }


def table_specs(table: "PC.PageTable", mesh: Mesh):
    """`PageTable` bookkeeping (block tables, per-slot lengths/positions,
    free stack, and the prefix-sharing ``refcount``) is tiny and read by
    every layer — replicated.  The tree-map keeps this future-proof: new
    bookkeeping arrays (``refcount`` arrived with prefix caching) pick up
    the replicated spec without touching the sharded serving path."""
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), table)


def slot_state_specs(slots, mesh: Mesh):
    """Device-resident per-slot request state
    (:class:`~repro.serving.scheduler.SlotState`: generated/budget/done,
    ``[R]`` each) rides the megastep carry next to the page table — tiny
    shared bookkeeping, replicated like it."""
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), slots)


def scratch_specs(scratch, mesh: Mesh, stacked: bool = False):
    """Spec tree for one layer's transient :class:`PrefillScratch`
    (``stacked`` = the scan-stacked super-block variant, one lead axis)."""
    return _cache_spec(scratch, mesh, False, 1 if stacked else 0)


def snapshot_specs(planes, mesh: Mesh):
    """Spec tree for a host-tier slot snapshot (core/host_tier.py) being
    swapped back onto the mesh.

    Every gathered leaf — pool planes ``[NBmax, G|1, H, D*]`` and fp
    double-buffer rows ``[2G, H, D]``, each with an optional leading
    scan-repeat axis — keeps its kv-head axis at position ``-2``, so the
    swap-in lands already head-sharded over ``model`` (matching the pool
    placement the resume scatter writes into) with everything else
    replicated.  `_fit` drops the spec where heads don't divide the mesh,
    mirroring the pool's own fallback."""
    def leaf_spec(leaf):
        parts = [None] * (np.ndim(leaf) - 2) + ["model", None]
        return _fit(mesh, np.shape(leaf), parts)

    return jax.tree.map(leaf_spec, planes)


def replicated(tree, mesh: Mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def apply_sharding_to_shapes(shapes, shardings):
    """Attach NamedShardings to a ShapeDtypeStruct pytree (for .lower())."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
