"""Logical-axis sharding (MaxText-style).

Model code annotates tensors with *logical* axis names
(`constrain(x, "batch", "seq", "embed")`); a rules table maps logical names
to mesh axes per execution mode. Outside a mesh context everything no-ops,
so the same model code runs on 1 CPU device and on a 512-chip mesh.

Mesh axes:
    single pod : ("data", "model")            = (16, 16)
    multi-pod  : ("pod", "data", "model")     = (2, 16, 16)

The "pod" axis (slow DCI links) only ever carries data parallelism
(gradient all-reduce in training, batch/sequence splits in serving) — never
tensor parallelism, which would put per-layer collectives on the slow links.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_state = threading.local()


# ---------------------------------------------------------------------------
# rules tables: logical axis -> mesh axis (or None = replicate)
# ---------------------------------------------------------------------------

# Training: FSDP — weights sharded over BOTH data and model axes so that a
# 123B model's AdamW state fits a v5e pod (16 GB/chip); activations sharded
# batch->data, heads/ff->model.
TRAIN_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "kv_seq": "model",   # fallback: sequence-parallel attention logits
    "head_dim": None,
    "mlp": "model",
    "moe_mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": "data",
    "layers": None,
    "fsdp_in": "data",       # weight d_in axis (FSDP shard)
    "ssm_inner": "model",
    "conv_dim": None,
    "state_dim": None,
    "codebooks": None,
    "img_seq": None,
}

# Serving: weights sharded over model axis only (replicated over data so
# every data-replica can decode independently); KV cache batch->data,
# kv_heads->model. long-context batch-1: cache *sequence* -> data.
SERVE_RULES = dict(TRAIN_RULES)
SERVE_RULES.update({
    "fsdp_in": None,
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
    "cache_kv_heads": "model",
})

# long_500k (batch=1): shard the KV cache sequence across the data axis —
# chip-level flash-decoding. Queries replicated; partial-softmax combine is
# inserted by SPMD.
LONG_RULES = dict(SERVE_RULES)
LONG_RULES.update({
    "batch": None,
    "cache_batch": None,
    "cache_seq": ("pod", "data"),
    "seq": ("pod", "data"),
})

RULESETS = {"train": TRAIN_RULES, "serve": SERVE_RULES, "long": LONG_RULES}


# ---------------------------------------------------------------------------
# context
# ---------------------------------------------------------------------------

@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], mode: str = "serve"):
    """Activate logical->mesh mapping for `constrain` calls under `mesh`."""
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, RULESETS[mode]) if mesh is not None else None
    try:
        yield
    finally:
        _state.ctx = prev


def current_mesh() -> Optional[Mesh]:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def model_parallel_size(mesh: Optional[Mesh] = None) -> int:
    """Extent of the tensor-parallel `model` axis of the active (or given)
    mesh; 1 when no mesh / no model axis — the single-device fast paths."""
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]


def data_parallel_size(mesh: Optional[Mesh] = None) -> int:
    """Extent of the batch/slot `data` axis of the active (or given) mesh."""
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None or "data" not in mesh.axis_names:
        return 1
    return mesh.shape["data"]


def logical_to_spec(*logical: Optional[str], rules=None) -> P:
    ctx = getattr(_state, "ctx", None)
    if rules is None:
        if ctx is None:
            return P()
        rules = ctx[1]
    mesh = ctx[0] if ctx else None
    used = set()
    parts = []
    for name in logical:
        ax = rules.get(name) if name else None
        if ax is None:
            parts.append(None)
            continue
        cand = ax if isinstance(ax, tuple) else (ax,)
        if mesh is not None:
            cand = tuple(a for a in cand if a in mesh.axis_names and a not in used)
        else:
            cand = tuple(a for a in cand if a not in used)
        used.update(cand)
        parts.append(cand if len(cand) > 1 else (cand[0] if cand else None))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def constrain(x: jnp.ndarray, *logical: Optional[str]) -> jnp.ndarray:
    """Apply a logical sharding constraint; no-op outside a mesh context.

    Shape-aware in a single pass: an axis whose size doesn't divide the mesh
    extent is skipped *and doesn't consume the mesh axis*, so a later
    logical axis can claim it (e.g. 36 query heads can't take the 16-way
    `model` axis → the kv-sequence axis gets it instead: sequence-parallel
    attention as the fallback for odd head counts)."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    used = set()
    parts = []
    for i, name in enumerate(logical[: x.ndim]):
        ax = rules.get(name) if name else None
        if ax is None:
            parts.append(None)
            continue
        cand = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,))
                     if a in mesh.axis_names and a not in used)
        extent = 1
        for a in cand:
            extent *= mesh.shape[a]
        if not cand or extent <= 1 or x.shape[i] % extent != 0:
            parts.append(None)
            continue
        used.update(cand)
        parts.append(cand if len(cand) > 1 else cand[0])
    while parts and parts[-1] is None:
        parts.pop()
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


def named_sharding(mesh: Mesh, *logical, mode: str = "serve") -> NamedSharding:
    spec = _spec_for(mesh, logical, RULESETS[mode])
    return NamedSharding(mesh, spec)


def _spec_for(mesh, logical, rules) -> P:
    used = set()
    parts = []
    for name in logical:
        ax = rules.get(name) if name else None
        if ax is None:
            parts.append(None)
            continue
        cand = ax if isinstance(ax, tuple) else (ax,)
        cand = tuple(a for a in cand if a in mesh.axis_names and a not in used)
        used.update(cand)
        parts.append(cand if len(cand) > 1 else (cand[0] if cand else None))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)
