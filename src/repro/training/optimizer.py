"""AdamW in pure JAX (no optax in this environment).

Optimizer state is sharded like the parameters (the FSDP rules in
distributed/sharding.py apply to `m`/`v` through the in_shardings of the
jitted train step), which is what lets 123B-scale training fit a v5e pod.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


class AdamW:
    def __init__(self, lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.01,
                 grad_clip=1.0, warmup_steps=100, total_steps=10_000):
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps
        self.weight_decay = weight_decay
        self.grad_clip = grad_clip
        self.warmup_steps = warmup_steps
        self.total_steps = total_steps

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=zeros(params), v=zeros(params))

    def schedule(self, step):
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - self.warmup_steps)
                        / max(self.total_steps - self.warmup_steps, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return self.lr * warm * (0.1 + 0.9 * cos)

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self.schedule(step.astype(jnp.float32))

        # global-norm clip
        leaves = jax.tree.leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in leaves))
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))

        b1, b2 = self.b1, self.b2

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / (1 - b1 ** step.astype(jnp.float32))
            vh = v / (1 - b2 ** step.astype(jnp.float32))
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        # three maps (XLA CSEs the duplicated math) — avoids tuple-leaf
        # ambiguity in param trees that legitimately contain tuples
        new_params = jax.tree.map(
            lambda g, m, v, p: upd(g, m, v, p)[0],
            grads, state.m, state.v, params)
        new_m = jax.tree.map(
            lambda g, m, v, p: upd(g, m, v, p)[1],
            grads, state.m, state.v, params)
        new_v = jax.tree.map(
            lambda g, m, v, p: upd(g, m, v, p)[2],
            grads, state.m, state.v, params)
        return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm
