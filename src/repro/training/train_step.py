"""LM training step: next-token cross-entropy (+ MoE aux loss), AdamW."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.stack import StackModel
from repro.training.optimizer import AdamW


def lm_loss(model: StackModel, params, batch) -> tuple[jnp.ndarray, dict]:
    """batch: {'tokens': [B,S] or [B,S,K], optional 'memory': [B,M,d]}.
    Next-token CE over positions 0..S-2."""
    tokens = batch["tokens"]
    memory = batch.get("memory")
    logits, aux = model.train_logits(params, tokens, memory=memory)
    logits = logits[:, :-1]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(nll)  # mean over B, S (and K for codebooks)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux,
                  "ppl": jnp.exp(jnp.clip(ce, max=20.0))}


def make_train_step(model: StackModel, optimizer: AdamW):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(model, p, batch), has_aux=True)(params)
        new_params, new_opt, gnorm = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_params, new_opt, metrics

    return train_step


def make_eval_ppl(model: StackModel):
    def eval_step(params, batch):
        _, metrics = lm_loss(model, params, batch)
        return metrics["ce"]

    return eval_step
