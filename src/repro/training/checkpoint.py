"""Checkpointing: flat-path .npz + JSON treedef (no orbax offline)."""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(p): np.asarray(v) for p, v in flat}


def save_checkpoint(path: str, params, opt_state=None, step: int = 0,
                    metadata=None):
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(path, "opt_state.npz"), **_flatten(opt_state))
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"step": step, "metadata": metadata or {}}, f)


def load_checkpoint(path: str, params_template, opt_template=None):
    """Restores arrays into the structure of the given templates."""
    def restore(npz_path, template):
        data = np.load(npz_path)
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, t in flat:
            key = jax.tree_util.keystr(p)
            arr = jnp.asarray(data[key])
            assert arr.shape == t.shape, (key, arr.shape, t.shape)
            leaves.append(arr.astype(t.dtype))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)

    params = restore(os.path.join(path, "params.npz"), params_template)
    out = [params]
    if opt_template is not None:
        out.append(restore(os.path.join(path, "opt_state.npz"), opt_template))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    out.append(meta["step"])
    return tuple(out)
